//! The autoscaler control loop: signals → policy → planner → actuation.
//!
//! A background thread samples a [`SignalProbe`] every
//! `sample_interval`, hands the snapshot to a [`ScalingPolicy`] (which
//! answers with a [`ScalingIntent`]), runs the intent through the
//! [`Planner`] (which costs it against per-framework extension models
//! and broker-tier saturation, deferring or resizing scale-ups that
//! cannot pay for themselves), and executes the resulting
//! [`ScalingPlan`] step by step through the pilot service: broker
//! extensions call [`PilotComputeService::extend_pilot`] on the broker
//! pilot, repartitions move the topic's partition set, processing
//! extensions extend the target pilot (paper Listing 4) and shrinks pop
//! extension pilots.  Every executed step — and every cost-aware
//! deferral — lands on a [`ScalingTimeline`] with its modeled cost and
//! its detection→Running reaction latency, so experiments can plot the
//! resource footprint against the input rate.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::broker::BrokerCluster;
use crate::engine::JobStats;
use crate::metrics::{ScalingAction, ScalingEvent, ScalingTimeline};
use crate::pilot::{Pilot, PilotComputeService};
use crate::util::{CircuitBreaker, CircuitBreakerConfig};

use super::planner::{PlanStep, Planner, PlannerConfig};
use super::policy::ScalingPolicy;
use super::signals::SignalProbe;

/// Control-loop configuration.
#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    /// Topic whose consumer lag drives the loop.
    pub topic: String,
    /// Consumer group owning the committed offsets (the streaming job's
    /// group for micro-batch consumers).
    pub group: String,
    /// How often signals are sampled.
    pub sample_interval: Duration,
    /// Ceiling on nodes added beyond the target pilot's base allocation.
    pub max_extension_nodes: usize,
    /// Largest single extension request (nodes per scale-up action).
    pub max_step: usize,
    /// The consumer job's micro-batch window (for overrun signals).
    pub window: Duration,
    /// Planner tuning (drain horizon, per-node I/O budgets, broker
    /// co-scheduling).  `max_step` and the framework kinds are derived
    /// from this config and the target pilots at spawn time.
    pub planner: PlannerConfig,
    /// Circuit breaker guarding every pilot actuation (extend/stop): a
    /// flapping framework trips the breaker Open and the loop keeps
    /// sampling instead of hammering doomed calls every tick.
    pub breaker: CircuitBreakerConfig,
    /// Dataflow-DAG `(topic, group)` consumer edges whose lags ride
    /// along in every snapshot's
    /// [`super::SignalSnapshot::edge_lags`] — observability across the
    /// whole DAG while the loop actuates on its own stage only.
    pub edges: Vec<(String, String)>,
}

impl AutoscalerConfig {
    pub fn new(topic: &str, group: &str) -> Self {
        AutoscalerConfig {
            topic: topic.to_string(),
            group: group.to_string(),
            sample_interval: Duration::from_millis(250),
            max_extension_nodes: 4,
            max_step: 1,
            window: Duration::from_secs(1),
            planner: PlannerConfig::default(),
            breaker: CircuitBreakerConfig::default(),
            edges: Vec::new(),
        }
    }

    pub fn with_sample_interval(mut self, interval: Duration) -> Self {
        self.sample_interval = interval.max(Duration::from_millis(1));
        self
    }

    pub fn with_max_extension_nodes(mut self, nodes: usize) -> Self {
        self.max_extension_nodes = nodes;
        self
    }

    pub fn with_max_step(mut self, nodes: usize) -> Self {
        self.max_step = nodes.max(1);
        self
    }

    pub fn with_window(mut self, window: Duration) -> Self {
        self.window = window;
        self
    }

    pub fn with_planner(mut self, planner: PlannerConfig) -> Self {
        self.planner = planner;
        self
    }

    pub fn with_breaker(mut self, breaker: CircuitBreakerConfig) -> Self {
        self.breaker = breaker;
        self
    }

    pub fn with_edges(mut self, edges: Vec<(String, String)>) -> Self {
        self.edges = edges;
        self
    }
}

/// A running autoscaler.  Dropping it stops the control loop; live
/// extension pilots are returned by [`stop`](Autoscaler::stop) so the
/// caller decides whether to keep or release the remaining footprint.
pub struct Autoscaler {
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
    timeline: Arc<ScalingTimeline>,
    extensions: Arc<Mutex<Vec<Arc<Pilot>>>>,
    broker_extensions: Arc<Mutex<Vec<Arc<Pilot>>>>,
}

impl Autoscaler {
    /// Start the control loop for `target` (a running base pilot whose
    /// framework consumes `config.topic`).  `stats` — the consuming
    /// job's stats, when the consumer is a micro-batch job — adds the
    /// window-overrun signals to each snapshot.  Plans that co-schedule
    /// broker extensions are only possible through
    /// [`Autoscaler::spawn_with_broker`]; this entry point plans with
    /// the broker tier pinned (broker steps are skipped).
    pub fn spawn(
        service: Arc<PilotComputeService>,
        target: Arc<Pilot>,
        cluster: BrokerCluster,
        stats: Option<Arc<JobStats>>,
        policy: Box<dyn ScalingPolicy>,
        config: AutoscalerConfig,
    ) -> Self {
        Self::spawn_with_broker(service, target, None, cluster, stats, policy, config)
    }

    /// [`Autoscaler::spawn`] plus a broker-tier pilot the planner may
    /// extend: when a repartition would oversubscribe per-node I/O
    /// budgets, or the broker saturation gauges cross their threshold,
    /// the plan's `ExtendBroker` steps actuate on `broker_target`.
    pub fn spawn_with_broker(
        service: Arc<PilotComputeService>,
        target: Arc<Pilot>,
        broker_target: Option<Arc<Pilot>>,
        cluster: BrokerCluster,
        stats: Option<Arc<JobStats>>,
        policy: Box<dyn ScalingPolicy>,
        config: AutoscalerConfig,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let timeline = Arc::new(ScalingTimeline::new());
        let extensions: Arc<Mutex<Vec<Arc<Pilot>>>> = Arc::new(Mutex::new(Vec::new()));
        let broker_extensions: Arc<Mutex<Vec<Arc<Pilot>>>> = Arc::new(Mutex::new(Vec::new()));
        let probe = SignalProbe::new(
            cluster.clone(),
            &config.topic,
            &config.group,
            stats,
            config.window.as_secs_f64(),
        )
        .with_edges(config.edges.clone());
        // The planner's cost model keys off the real framework kinds;
        // its step ceiling mirrors the controller's.
        let mut planner_config = config.planner.clone().with_max_step(config.max_step);
        planner_config.processing_framework = target.framework();
        if let Some(broker) = &broker_target {
            planner_config.broker_framework = broker.framework();
        } else {
            // No broker pilot to extend: plans must not contain broker
            // steps (a saturated tier is still visible on the timeline
            // via the gauges the policy sees).
            planner_config.max_broker_step = 0;
        }
        let planner = Planner::new(planner_config);
        let thread = {
            let stop = stop.clone();
            let timeline = timeline.clone();
            let extensions = extensions.clone();
            let broker_extensions = broker_extensions.clone();
            std::thread::Builder::new()
                .name(format!("autoscaler-{}", config.topic))
                .spawn(move || {
                    let breaker = CircuitBreaker::new(config.breaker);
                    let mut loop_state = ControlLoop {
                        service,
                        target,
                        broker_target,
                        cluster,
                        planner,
                        config,
                        timeline,
                        extensions,
                        broker_extensions,
                        breaker,
                    };
                    loop_state.run(probe, policy, stop)
                })
                .expect("spawn autoscaler thread")
        };
        Autoscaler {
            stop,
            thread: Some(thread),
            timeline,
            extensions,
            broker_extensions,
        }
    }

    /// The recorded scaling events (shared; updates live).
    pub fn timeline(&self) -> Arc<ScalingTimeline> {
        self.timeline.clone()
    }

    /// Processing extension pilots currently held by the loop.
    pub fn extension_count(&self) -> usize {
        self.extensions.lock().unwrap().len()
    }

    /// Broker extension pilots currently held by the loop.
    pub fn broker_extension_count(&self) -> usize {
        self.broker_extensions.lock().unwrap().len()
    }

    /// Stop the control loop and return any extension pilots still
    /// running — processing extensions first, then broker extensions
    /// (empty when the policy already scaled back down).
    pub fn stop(mut self) -> Vec<Arc<Pilot>> {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        let mut pilots = std::mem::take(&mut *self.extensions.lock().unwrap());
        pilots.extend(std::mem::take(&mut *self.broker_extensions.lock().unwrap()));
        pilots
    }
}

impl Drop for Autoscaler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Everything the control thread owns while running.
struct ControlLoop {
    service: Arc<PilotComputeService>,
    target: Arc<Pilot>,
    broker_target: Option<Arc<Pilot>>,
    cluster: BrokerCluster,
    planner: Planner,
    config: AutoscalerConfig,
    timeline: Arc<ScalingTimeline>,
    extensions: Arc<Mutex<Vec<Arc<Pilot>>>>,
    broker_extensions: Arc<Mutex<Vec<Arc<Pilot>>>>,
    /// Guards every extend/stop against a flapping pilot framework.
    breaker: CircuitBreaker,
}

impl ControlLoop {
    fn run(
        &mut self,
        mut probe: SignalProbe,
        mut policy: Box<dyn ScalingPolicy>,
        stop: Arc<AtomicBool>,
    ) {
        let started = Instant::now();
        let min_nodes = self.target.nodes().len();
        let max_nodes = min_nodes + self.config.max_extension_nodes;

        while !stop.load(Ordering::Relaxed) {
            std::thread::sleep(self.config.sample_interval);
            if stop.load(Ordering::Relaxed) {
                break;
            }
            let extension_nodes: usize = self
                .extensions
                .lock()
                .unwrap()
                .iter()
                .map(|p| p.nodes().len())
                .sum();
            let nodes = min_nodes + extension_nodes;
            let t = started.elapsed().as_secs_f64();
            let Ok(snapshot) = probe.sample(t, nodes, min_nodes, max_nodes) else {
                continue; // topic gone (e.g. broker stopped mid-shutdown)
            };
            let policy_name = policy.name();
            // Broker-node deaths handled by the cluster's failover path
            // land on this loop's timeline with their measured recovery
            // time, so experiments see failovers next to scale-ups on
            // one axis (and the degraded-replication signal the planner
            // acts on below has a visible cause).
            for ev in self.cluster.take_failover_events() {
                self.timeline.record(ScalingEvent {
                    at_secs: t,
                    action: ScalingAction::Failover,
                    delta_nodes: ev.promoted + ev.unreplicated,
                    total_nodes: self.cluster.broker_nodes().len(),
                    lag: snapshot.lag,
                    partitions: snapshot.partitions,
                    policy: "failover".to_string(),
                    reaction_secs: ev.recovery_secs,
                    cost_secs: ev.recovery_secs,
                    lost_records: ev.lost_records,
                });
            }
            self.release_idle_broker_extensions(&snapshot, t, policy_name);
            let intent = policy.decide(&snapshot);
            let plan = self.planner.plan(intent, &snapshot);
            if let Some(reason) = plan.deferred {
                // Cost-aware deferral is itself a decision: record it
                // so experiments can audit what the planner declined.
                self.timeline.record(ScalingEvent {
                    at_secs: t,
                    action: ScalingAction::Defer,
                    delta_nodes: 0,
                    total_nodes: nodes,
                    lag: snapshot.lag,
                    partitions: snapshot.partitions,
                    policy: format!("{policy_name}/{reason:?}"),
                    reaction_secs: 0.0,
                    cost_secs: 0.0,
                    lost_records: 0,
                });
                continue;
            }
            if plan.is_hold() {
                continue;
            }
            // A plan that pairs a repartition with a processing
            // extension must not touch the topic if no node can
            // actually be added (machine full, ceiling raced) —
            // otherwise a standing backlog would grow the partition
            // count every cooldown with nothing new to consume it.
            let planned_up = plan.added_processing_nodes();
            if planned_up > 0
                && (plan.repartition_target().is_some() || plan.added_broker_nodes() > 0)
            {
                // The plan's own broker step consumes free nodes before
                // the processing extension runs, so it must be counted
                // here — otherwise the topic could grow (or the last
                // free node go to a broker pilot) while the processing
                // extension comes up empty, and nothing would ever
                // release that broker capacity.
                let free_after_broker = self
                    .service
                    .machine()
                    .free_nodes()
                    .saturating_sub(plan.added_broker_nodes());
                let step = planned_up.min(max_nodes - nodes).min(free_after_broker);
                if step == 0 {
                    continue;
                }
            }
            // Partition count to stamp on subsequent events: a
            // repartition step earlier in the plan moves it.
            let mut live_partitions = snapshot.partitions;
            for step in &plan.steps {
                match *step {
                    PlanStep::ExtendBroker { nodes: broker_nodes, cost } => {
                        let added = self.extend_broker(
                            broker_nodes,
                            cost.lead_secs,
                            &snapshot,
                            t,
                            policy_name,
                        );
                        if added < broker_nodes {
                            // The rest of the plan (the repartition's
                            // partition count especially) is sized for
                            // broker capacity that didn't materialize
                            // (machine raced full / extend failed):
                            // abandon it; the policy's cooldown paces
                            // the retry.
                            break;
                        }
                    }
                    PlanStep::Repartition { partitions, cost } => {
                        // Move the one-task-per-partition cap first, so
                        // the extension that follows is immediately
                        // useful.  Topic gone (shutdown race): abandon
                        // the rest of the plan for this tick.
                        if self.cluster.repartition_topic(&self.config.topic, partitions).is_err() {
                            break;
                        }
                        live_partitions = partitions;
                        self.timeline.record(ScalingEvent {
                            at_secs: t,
                            action: ScalingAction::Repartition,
                            delta_nodes: 0,
                            total_nodes: nodes,
                            lag: snapshot.lag,
                            partitions,
                            policy: policy_name.to_string(),
                            reaction_secs: 0.0,
                            cost_secs: cost.lead_secs,
                            lost_records: 0,
                        });
                    }
                    PlanStep::ExtendProcessing { nodes: up, cost } => {
                        self.extend_processing(
                            up,
                            cost.lead_secs,
                            nodes,
                            max_nodes,
                            live_partitions,
                            &snapshot,
                            t,
                            policy_name,
                        );
                    }
                    PlanStep::ShrinkProcessing { nodes: down } => {
                        self.shrink_processing(down, nodes, min_nodes, &snapshot, t, policy_name);
                    }
                    PlanStep::ReassignReplicas { moves: planned_moves, cost } => {
                        // Placement repair on the existing tier: move
                        // follower replicas off crowded racks and hot
                        // brokers.  No nodes change hands, so the free
                        // machine capacity is irrelevant here.  Topic
                        // gone / cluster stopping: skip this tick.
                        let Ok(moved) = self.cluster.reassign_replicas() else {
                            break;
                        };
                        if moved == 0 {
                            // Placement already converged (the skew the
                            // snapshot saw was healed by a racing
                            // failover or an earlier tick): nothing to
                            // record.
                            continue;
                        }
                        self.timeline.record(ScalingEvent {
                            at_secs: t,
                            action: ScalingAction::ReassignReplicas,
                            // `delta_nodes` counts moved replicas, not
                            // nodes: the tier size is unchanged.
                            delta_nodes: moved,
                            total_nodes: self.cluster.broker_nodes().len(),
                            lag: snapshot.lag,
                            partitions: live_partitions,
                            policy: policy_name.to_string(),
                            reaction_secs: 0.0,
                            cost_secs: cost.lead_secs * moved as f64
                                / (planned_moves.max(1)) as f64,
                            lost_records: 0,
                        });
                    }
                }
            }
        }
    }

    /// Extend the broker tier by up to `broker_nodes`; returns the
    /// nodes actually added so the caller can abandon a plan whose
    /// broker capacity didn't materialize.
    fn extend_broker(
        &self,
        broker_nodes: usize,
        cost_secs: f64,
        snapshot: &super::signals::SignalSnapshot,
        t: f64,
        policy_name: &str,
    ) -> usize {
        let Some(broker) = &self.broker_target else {
            return 0; // planner config disables broker steps in this case
        };
        let step = broker_nodes.min(self.service.machine().free_nodes());
        if step == 0 {
            return 0;
        }
        let detected = Instant::now();
        if let Ok(ext) = self.breaker.call(|| self.service.extend_pilot(broker, step)) {
            self.broker_extensions.lock().unwrap().push(ext);
            self.timeline.record(ScalingEvent {
                at_secs: t,
                action: ScalingAction::BrokerUp,
                delta_nodes: step,
                total_nodes: snapshot.broker_nodes + step,
                lag: snapshot.lag,
                partitions: snapshot.partitions,
                policy: policy_name.to_string(),
                reaction_secs: detected.elapsed().as_secs_f64(),
                cost_secs,
                lost_records: 0,
            });
            return step;
        }
        // On error: lost a race for the last free nodes; the policy's
        // cooldown spaces out the retry.
        0
    }

    #[allow(clippy::too_many_arguments)]
    fn extend_processing(
        &self,
        up: usize,
        cost_secs: f64,
        nodes: usize,
        max_nodes: usize,
        partitions: usize,
        snapshot: &super::signals::SignalSnapshot,
        t: f64,
        policy_name: &str,
    ) {
        // The planner already sized the step (max_step, ceiling,
        // cost/benefit); re-clamp only against what changed since the
        // snapshot: live headroom and free machine nodes.
        let step = up
            .min(max_nodes - nodes)
            .min(self.service.machine().free_nodes());
        if step == 0 {
            // Ceiling reached or machine full.  The policy has already
            // charged its cooldown for this decision, which doubles as
            // backoff before the next attempt.
            return;
        }
        let detected = Instant::now();
        // extend_pilot blocks through queue + bootstrap, so the elapsed
        // time is the full detection→Running latency.
        if let Ok(ext) = self.breaker.call(|| self.service.extend_pilot(&self.target, step)) {
            self.extensions.lock().unwrap().push(ext);
            self.timeline.record(ScalingEvent {
                at_secs: t,
                action: ScalingAction::Up,
                delta_nodes: step,
                total_nodes: nodes + step,
                lag: snapshot.lag,
                partitions,
                policy: policy_name.to_string(),
                reaction_secs: detected.elapsed().as_secs_f64(),
                cost_secs,
                lost_records: 0,
            });
        }
    }

    fn shrink_processing(
        &self,
        down: usize,
        nodes: usize,
        min_nodes: usize,
        snapshot: &super::signals::SignalSnapshot,
        t: f64,
        policy_name: &str,
    ) {
        // Pop whole extension pilots until ~down nodes are gone
        // (extensions are indivisible; the last pop may release a few
        // more than requested, never dropping below the base
        // allocation).
        let mut removed = 0;
        while removed < down {
            let Some(ext) = self.extensions.lock().unwrap().pop() else {
                break;
            };
            let ext_nodes = ext.nodes().len();
            match self.breaker.call(|| self.service.stop_pilot(&ext)) {
                Ok(()) => removed += ext_nodes,
                Err(_) => {
                    // Keep tracking the pilot (it still holds nodes);
                    // retry on a later tick.
                    self.extensions.lock().unwrap().push(ext);
                    break;
                }
            }
        }
        if removed > 0 {
            self.timeline.record(ScalingEvent {
                at_secs: t,
                action: ScalingAction::Down,
                delta_nodes: removed,
                total_nodes: nodes - removed.min(nodes - min_nodes),
                lag: snapshot.lag,
                partitions: snapshot.partitions,
                policy: policy_name.to_string(),
                reaction_secs: 0.0,
                cost_secs: 0.0,
                lost_records: 0,
            });
        }
    }

    /// Release co-scheduled broker extensions the tier no longer needs.
    ///
    /// Runs every tick (so a failed `stop_pilot` really is retried):
    /// once the processing fleet is back at its base, broker capacity
    /// bought for a burst is released — but only while the *remaining*
    /// tier still covers the topic's partition count within the
    /// per-node I/O budget, so brokers co-scheduled with a repartition
    /// stay for as long as the partitions they serve do, and repeated
    /// burst cycles never accumulate saturation-driven broker pilots.
    fn release_idle_broker_extensions(
        &self,
        snapshot: &super::signals::SignalSnapshot,
        t: f64,
        policy_name: &str,
    ) {
        if !self.extensions.lock().unwrap().is_empty() {
            return;
        }
        let budget = self.planner.config().partitions_per_broker_node.max(1);
        loop {
            let Ok(partitions) = self.cluster.partition_count(&self.config.topic) else {
                return; // topic gone (shutdown race)
            };
            let brokers = self.cluster.broker_nodes().len();
            let ext = {
                let mut held = self.broker_extensions.lock().unwrap();
                // Pop only if the tier minus this extension still
                // serves every partition within budget.
                let can_pop = held
                    .last()
                    .map(|e| partitions <= brokers.saturating_sub(e.nodes().len()) * budget)
                    .unwrap_or(false);
                if can_pop {
                    held.pop()
                } else {
                    None
                }
            };
            let Some(ext) = ext else {
                break;
            };
            let ext_nodes = ext.nodes().len();
            match self.breaker.call(|| self.service.stop_pilot(&ext)) {
                Ok(()) => {
                    self.timeline.record(ScalingEvent {
                        at_secs: t,
                        action: ScalingAction::BrokerDown,
                        delta_nodes: ext_nodes,
                        total_nodes: brokers.saturating_sub(ext_nodes),
                        lag: snapshot.lag,
                        partitions,
                        policy: policy_name.to_string(),
                        reaction_secs: 0.0,
                        cost_secs: 0.0,
                        lost_records: 0,
                    });
                }
                Err(_) => {
                    // Still holds nodes; retried next tick.
                    self.broker_extensions.lock().unwrap().push(ext);
                    break;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::policy::ThresholdPolicy;
    use crate::cluster::Machine;
    use crate::metrics::ScalingAction;
    use crate::pilot::SparkDescription;

    fn wait_until(mut cond: impl FnMut() -> bool, secs: f64) -> bool {
        let t0 = Instant::now();
        while t0.elapsed().as_secs_f64() < secs {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        false
    }

    #[test]
    fn controller_extends_on_lag_and_shrinks_after_drain() {
        let service = Arc::new(PilotComputeService::new(Machine::unthrottled(5)));
        let (kafka, cluster) = service
            .start_kafka(crate::pilot::KafkaDescription::new(1))
            .unwrap();
        let (spark, engine) = service
            .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
            .unwrap();
        cluster.create_topic("load", 2).unwrap();

        let policy = ThresholdPolicy::new(10, 1)
            .with_sustain(1)
            .with_cooldown_secs(0.1)
            .with_step(2);
        let scaler = Autoscaler::spawn(
            service.clone(),
            spark.clone(),
            cluster.clone(),
            None,
            Box::new(policy),
            AutoscalerConfig::new("load", "g")
                .with_sample_interval(Duration::from_millis(20))
                .with_max_extension_nodes(2)
                .with_max_step(2),
        );

        // Backpressure: 40 uncommitted messages.
        for i in 0..40u8 {
            cluster.produce("load", (i % 2) as usize, 0, &[vec![i]]).unwrap();
        }
        assert!(
            wait_until(|| scaler.extension_count() == 1, 5.0),
            "no scale-up within 5s"
        );
        assert_eq!(engine.executor_count(), 3, "1 base + 2 extension nodes");

        // Drain: commit everything; the policy must scale back down.
        cluster.commit("g", "load", 0, 20);
        cluster.commit("g", "load", 1, 20);
        assert!(
            wait_until(|| scaler.extension_count() == 0, 5.0),
            "no scale-down within 5s"
        );

        let remaining = scaler.stop();
        assert!(remaining.is_empty());
        // 5 - kafka(1) - spark(1): extension nodes back in the pool.
        assert_eq!(service.machine().free_nodes(), 3);
        service.stop_pilot(&spark).unwrap();
        service.stop_pilot(&kafka).unwrap();
    }

    #[test]
    fn controller_repartitions_before_extending_past_the_cap() {
        use crate::autoscale::policy::PartitionElastic;

        let service = Arc::new(PilotComputeService::new(Machine::unthrottled(5)));
        let (kafka, cluster) = service
            .start_kafka(crate::pilot::KafkaDescription::new(1))
            .unwrap();
        let (spark, _engine) = service
            .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
            .unwrap();
        cluster.create_topic("capped", 1).unwrap();

        let inner = ThresholdPolicy::new(10, 1)
            .with_sustain(1)
            .with_cooldown_secs(0.1)
            .with_step(2);
        let scaler = Autoscaler::spawn(
            service.clone(),
            spark.clone(),
            cluster.clone(),
            None,
            Box::new(PartitionElastic::new(inner, 1)),
            AutoscalerConfig::new("capped", "g")
                .with_sample_interval(Duration::from_millis(20))
                .with_max_extension_nodes(2)
                .with_max_step(2),
        );
        // Standing lag on the single partition: the wrapped policy must
        // repartition to 3 (1 base + 2 extension slots) and extend.
        let batch: Vec<Vec<u8>> = (0..20u8).map(|i| vec![i]).collect();
        cluster.produce("capped", 0, 0, &batch).unwrap();

        let timeline = scaler.timeline();
        assert!(
            wait_until(|| timeline.count(ScalingAction::Repartition) >= 1, 5.0),
            "no repartition event"
        );
        assert!(
            wait_until(|| scaler.extension_count() >= 1, 5.0),
            "no extension after repartition"
        );
        assert_eq!(cluster.partition_count("capped").unwrap(), 3);
        let events = timeline.events();
        let rp = events
            .iter()
            .position(|e| e.action == ScalingAction::Repartition)
            .unwrap();
        let up = events.iter().position(|e| e.action == ScalingAction::Up).unwrap();
        assert!(rp < up, "repartition must precede the extension");
        assert_eq!(events[rp].partitions, 3);
        assert_eq!(events[rp].policy, "partition-elastic");

        for p in scaler.stop() {
            service.stop_pilot(&p).unwrap();
        }
        service.stop_pilot(&spark).unwrap();
        service.stop_pilot(&kafka).unwrap();
    }

    #[test]
    fn failover_events_drain_onto_the_controller_timeline() {
        use crate::broker::ReplicationConfig;

        let service = Arc::new(PilotComputeService::new(Machine::unthrottled(5)));
        let (kafka, cluster) = service
            .start_kafka(crate::pilot::KafkaDescription::new(2))
            .unwrap();
        let (spark, _engine) = service
            .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
            .unwrap();
        cluster
            .create_topic_replicated("ft", 2, ReplicationConfig::new(2))
            .unwrap();

        // Quiet policy: the loop only samples, drains failover events,
        // and (via the planner's repair branch) would plan a broker
        // replacement — which spawn() disables (no broker pilot).
        let policy = ThresholdPolicy::new(1_000, 0).with_cooldown_secs(0.05);
        let scaler = Autoscaler::spawn(
            service.clone(),
            spark.clone(),
            cluster.clone(),
            None,
            Box::new(policy),
            AutoscalerConfig::new("ft", "g").with_sample_interval(Duration::from_millis(20)),
        );

        let victim = cluster.broker_nodes()[1];
        cluster.kill_broker(victim).unwrap();

        let timeline = scaler.timeline();
        assert!(
            wait_until(|| timeline.count(ScalingAction::Failover) >= 1, 5.0),
            "no Failover event within 5s"
        );
        let events = timeline.events();
        let ev = events.iter().find(|e| e.action == ScalingAction::Failover).unwrap();
        assert_eq!(ev.policy, "failover");
        assert_eq!(ev.total_nodes, 1, "one broker left after the kill");
        assert!(ev.cost_secs >= 0.0, "recovery time is the event's cost");
        assert_eq!(ev.cost_secs, ev.reaction_secs);
        // The queue drained: no duplicate events on later ticks.
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(scaler.timeline().count(ScalingAction::Failover), 1);

        for p in scaler.stop() {
            let _ = service.stop_pilot(&p);
        }
        service.stop_pilot(&spark).unwrap();
        service.stop_pilot(&kafka).unwrap();
    }

    #[test]
    fn rack_skew_actuates_replica_reassignment_not_a_broker_extension() {
        use crate::broker::ReplicationConfig;

        let service = Arc::new(PilotComputeService::new(Machine::unthrottled(5)));
        let (kafka, cluster) = service
            .start_kafka(crate::pilot::KafkaDescription::new(4))
            .unwrap();
        let (spark, _engine) = service
            .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
            .unwrap();
        cluster.set_racks(2);
        cluster
            .create_topic_replicated("rr", 2, ReplicationConfig::new(2))
            .unwrap();

        // Manufacture placement debt before the loop starts: bounce the
        // whole of rack 1.  The rejoined brokers hold no replicas, so
        // every set is crowded onto rack 0 and the probe reports
        // rack_skew = 1.0 from the first sample.
        let victims: Vec<_> = cluster.kill_rack(1).unwrap().iter().map(|r| r.killed).collect();
        for v in victims {
            cluster.rejoin_broker(v).unwrap();
        }
        assert_eq!(cluster.rack_skew(), 1.0);

        // Quiet policy: every intent is Hold, so any action on the
        // timeline comes from the planner's repair branch.
        let policy = ThresholdPolicy::new(1_000, 0).with_cooldown_secs(0.05);
        let scaler = Autoscaler::spawn(
            service.clone(),
            spark.clone(),
            cluster.clone(),
            None,
            Box::new(policy),
            AutoscalerConfig::new("rr", "g").with_sample_interval(Duration::from_millis(20)),
        );

        let timeline = scaler.timeline();
        assert!(
            wait_until(|| timeline.count(ScalingAction::ReassignReplicas) >= 1, 5.0),
            "no ReassignReplicas event within 5s"
        );
        assert_eq!(cluster.rack_skew(), 0.0, "reassignment must heal the skew");
        let events = timeline.events();
        let ev = events
            .iter()
            .find(|e| e.action == ScalingAction::ReassignReplicas)
            .unwrap();
        assert_eq!(ev.policy, "threshold");
        assert!(ev.delta_nodes >= 1, "delta_nodes carries the moved-replica count");
        assert_eq!(ev.total_nodes, 4, "the tier itself never grew");
        assert!(ev.cost_secs > 0.0);
        assert_eq!(ev.lost_records, 0);
        // Placement repair is the *cheap* path: no broker pilot was
        // extended (spawn() has none to extend, and the reassign branch
        // must not require one), and once the skew is healed the
        // planner holds — no event spam on later ticks.
        assert_eq!(scaler.broker_extension_count(), 0);
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(timeline.count(ScalingAction::ReassignReplicas), 1);

        for p in scaler.stop() {
            let _ = service.stop_pilot(&p);
        }
        service.stop_pilot(&spark).unwrap();
        service.stop_pilot(&kafka).unwrap();
    }

    #[test]
    fn timeline_records_up_then_down_with_reaction_latency() {
        let service = Arc::new(PilotComputeService::new(Machine::unthrottled(4)));
        let (kafka, cluster) = service
            .start_kafka(crate::pilot::KafkaDescription::new(1))
            .unwrap();
        let (spark, _engine) = service
            .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
            .unwrap();
        cluster.create_topic("t", 1).unwrap();

        let policy = ThresholdPolicy::new(5, 0)
            .with_sustain(1)
            .with_cooldown_secs(0.05);
        let scaler = Autoscaler::spawn(
            service.clone(),
            spark.clone(),
            cluster.clone(),
            None,
            Box::new(policy),
            AutoscalerConfig::new("t", "g")
                .with_sample_interval(Duration::from_millis(20))
                .with_max_extension_nodes(1),
        );
        let batch: Vec<Vec<u8>> = (0..8u8).map(|i| vec![i]).collect();
        cluster.produce("t", 0, 0, &batch).unwrap();
        let timeline = scaler.timeline();
        assert!(
            wait_until(|| timeline.count(ScalingAction::Up) >= 1, 5.0),
            "no Up event"
        );
        cluster.commit("g", "t", 0, 8);
        assert!(
            wait_until(|| timeline.count(ScalingAction::Down) >= 1, 5.0),
            "no Down event"
        );
        for p in scaler.stop() {
            let _ = service.stop_pilot(&p);
        }
        let events = timeline.events();
        let up = events.iter().find(|e| e.action == ScalingAction::Up).unwrap();
        assert!(up.reaction_secs >= 0.0);
        assert_eq!(up.delta_nodes, 1);
        assert_eq!(up.policy, "threshold");
        assert!(up.lag >= 5);
        // The planner stamps the modeled Spark extension cost on the
        // event (one wave + settle).
        assert_eq!(up.cost_secs, 16.0);
        service.stop_pilot(&spark).unwrap();
        service.stop_pilot(&kafka).unwrap();
    }
}
