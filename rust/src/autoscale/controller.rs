//! The autoscaler control loop: signals → policy → pilot actuation.
//!
//! A background thread samples a [`SignalProbe`] every
//! `sample_interval`, hands the snapshot to a [`ScalingPolicy`], and
//! actuates decisions through the pilot service: scale-up calls
//! [`PilotComputeService::extend_pilot`] (paper Listing 4) and pushes
//! the extension onto a stack; scale-down pops extensions and stops
//! them, shrinking the framework back (paper §4.2).  Every acted-on
//! decision lands on a [`ScalingTimeline`] with its detection→Running
//! reaction latency, so experiments can plot the resource footprint
//! against the input rate.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::broker::BrokerCluster;
use crate::engine::JobStats;
use crate::metrics::{ScalingAction, ScalingEvent, ScalingTimeline};
use crate::pilot::{Pilot, PilotComputeService};

use super::policy::{PolicyDecision, ScalingPolicy};
use super::signals::SignalProbe;

/// Control-loop configuration.
#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    /// Topic whose consumer lag drives the loop.
    pub topic: String,
    /// Consumer group owning the committed offsets (the streaming job's
    /// group for micro-batch consumers).
    pub group: String,
    /// How often signals are sampled.
    pub sample_interval: Duration,
    /// Ceiling on nodes added beyond the target pilot's base allocation.
    pub max_extension_nodes: usize,
    /// Largest single extension request (nodes per scale-up action).
    pub max_step: usize,
    /// The consumer job's micro-batch window (for overrun signals).
    pub window: Duration,
}

impl AutoscalerConfig {
    pub fn new(topic: &str, group: &str) -> Self {
        AutoscalerConfig {
            topic: topic.to_string(),
            group: group.to_string(),
            sample_interval: Duration::from_millis(250),
            max_extension_nodes: 4,
            max_step: 1,
            window: Duration::from_secs(1),
        }
    }

    pub fn with_sample_interval(mut self, interval: Duration) -> Self {
        self.sample_interval = interval.max(Duration::from_millis(1));
        self
    }

    pub fn with_max_extension_nodes(mut self, nodes: usize) -> Self {
        self.max_extension_nodes = nodes;
        self
    }

    pub fn with_max_step(mut self, nodes: usize) -> Self {
        self.max_step = nodes.max(1);
        self
    }

    pub fn with_window(mut self, window: Duration) -> Self {
        self.window = window;
        self
    }
}

/// A running autoscaler.  Dropping it stops the control loop; live
/// extension pilots are returned by [`stop`](Autoscaler::stop) so the
/// caller decides whether to keep or release the remaining footprint.
pub struct Autoscaler {
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
    timeline: Arc<ScalingTimeline>,
    extensions: Arc<Mutex<Vec<Arc<Pilot>>>>,
}

impl Autoscaler {
    /// Start the control loop for `target` (a running base pilot whose
    /// framework consumes `config.topic`).  `stats` — the consuming
    /// job's stats, when the consumer is a micro-batch job — adds the
    /// window-overrun signals to each snapshot.
    pub fn spawn(
        service: Arc<PilotComputeService>,
        target: Arc<Pilot>,
        cluster: BrokerCluster,
        stats: Option<Arc<JobStats>>,
        policy: Box<dyn ScalingPolicy>,
        config: AutoscalerConfig,
    ) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let timeline = Arc::new(ScalingTimeline::new());
        let extensions: Arc<Mutex<Vec<Arc<Pilot>>>> = Arc::new(Mutex::new(Vec::new()));
        let probe = SignalProbe::new(
            cluster.clone(),
            &config.topic,
            &config.group,
            stats,
            config.window.as_secs_f64(),
        );
        let thread = {
            let stop = stop.clone();
            let timeline = timeline.clone();
            let extensions = extensions.clone();
            std::thread::Builder::new()
                .name(format!("autoscaler-{}", config.topic))
                .spawn(move || {
                    control_loop(
                        service, target, cluster, probe, policy, config, stop, timeline, extensions,
                    )
                })
                .expect("spawn autoscaler thread")
        };
        Autoscaler {
            stop,
            thread: Some(thread),
            timeline,
            extensions,
        }
    }

    /// The recorded scaling events (shared; updates live).
    pub fn timeline(&self) -> Arc<ScalingTimeline> {
        self.timeline.clone()
    }

    /// Extension pilots currently held by the loop.
    pub fn extension_count(&self) -> usize {
        self.extensions.lock().unwrap().len()
    }

    /// Stop the control loop and return any extension pilots still
    /// running (empty when the policy already scaled back down).
    pub fn stop(mut self) -> Vec<Arc<Pilot>> {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        std::mem::take(&mut *self.extensions.lock().unwrap())
    }
}

impl Drop for Autoscaler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn control_loop(
    service: Arc<PilotComputeService>,
    target: Arc<Pilot>,
    cluster: BrokerCluster,
    mut probe: SignalProbe,
    mut policy: Box<dyn ScalingPolicy>,
    config: AutoscalerConfig,
    stop: Arc<AtomicBool>,
    timeline: Arc<ScalingTimeline>,
    extensions: Arc<Mutex<Vec<Arc<Pilot>>>>,
) {
    let started = Instant::now();
    let min_nodes = target.nodes().len();
    let max_nodes = min_nodes + config.max_extension_nodes;

    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(config.sample_interval);
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let extension_nodes: usize = extensions
            .lock()
            .unwrap()
            .iter()
            .map(|p| p.nodes().len())
            .sum();
        let nodes = min_nodes + extension_nodes;
        let t = started.elapsed().as_secs_f64();
        let Ok(snapshot) = probe.sample(t, nodes, min_nodes, max_nodes) else {
            continue; // topic gone (e.g. broker stopped mid-shutdown)
        };
        let policy_name = policy.name().to_string();
        // Scale-up actuation shared by ScaleUp and Repartition: extend
        // the pilot by up to `n` nodes and record the event.
        let actuate_up = |n: usize, partitions: usize| {
            let step = n
                .min(config.max_step)
                .min(max_nodes - nodes)
                .min(service.machine().free_nodes());
            if step == 0 {
                // Ceiling reached or machine full.  The policy has
                // already charged its cooldown for this decision,
                // which doubles as backoff before the next attempt.
                return;
            }
            let detected = Instant::now();
            // extend_pilot blocks through queue + bootstrap, so the
            // elapsed time is the full detection→Running latency.
            if let Ok(ext) = service.extend_pilot(&target, step) {
                extensions.lock().unwrap().push(ext);
                timeline.record(ScalingEvent {
                    at_secs: t,
                    action: ScalingAction::Up,
                    delta_nodes: step,
                    total_nodes: nodes + step,
                    lag: snapshot.lag,
                    partitions,
                    policy: policy_name.clone(),
                    reaction_secs: detected.elapsed().as_secs_f64(),
                });
            }
            // On error: lost a race for the last free nodes; the
            // policy's cooldown spaces out the retry.
        };
        match policy.decide(&snapshot) {
            PolicyDecision::Hold => {}
            PolicyDecision::ScaleUp(n) => actuate_up(n, snapshot.partitions),
            PolicyDecision::Repartition { partitions, scale_up } => {
                // Clamp the extension before touching the topic: if no
                // node can actually be added (ceiling reached, machine
                // full), skip the repartition too — otherwise a standing
                // backlog would grow the partition count every cooldown
                // with nothing new to consume it.
                let step = scale_up
                    .min(config.max_step)
                    .min(max_nodes - nodes)
                    .min(service.machine().free_nodes());
                if step == 0 {
                    continue;
                }
                // Move the one-task-per-partition cap first, so the
                // extension that follows is immediately useful.
                match cluster.repartition_topic(&config.topic, partitions) {
                    Ok(_) => {
                        timeline.record(ScalingEvent {
                            at_secs: t,
                            action: ScalingAction::Repartition,
                            delta_nodes: 0,
                            total_nodes: nodes,
                            lag: snapshot.lag,
                            partitions,
                            policy: policy_name.clone(),
                            reaction_secs: 0.0,
                        });
                        actuate_up(step, partitions);
                    }
                    // Topic gone (shutdown race): skip this tick.
                    Err(_) => continue,
                }
            }
            PolicyDecision::ScaleDown(n) => {
                // Pop whole extension pilots until ~n nodes are gone
                // (extensions are indivisible; the last pop may release
                // a few more than requested, never dropping below the
                // base allocation).
                let mut removed = 0;
                while removed < n {
                    let Some(ext) = extensions.lock().unwrap().pop() else {
                        break;
                    };
                    let ext_nodes = ext.nodes().len();
                    match service.stop_pilot(&ext) {
                        Ok(()) => removed += ext_nodes,
                        Err(_) => {
                            // Keep tracking the pilot (it still holds
                            // nodes); retry on a later tick.
                            extensions.lock().unwrap().push(ext);
                            break;
                        }
                    }
                }
                if removed > 0 {
                    timeline.record(ScalingEvent {
                        at_secs: t,
                        action: ScalingAction::Down,
                        delta_nodes: removed,
                        total_nodes: nodes - removed.min(nodes - min_nodes),
                        lag: snapshot.lag,
                        partitions: snapshot.partitions,
                        policy: policy_name.clone(),
                        reaction_secs: 0.0,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::policy::ThresholdPolicy;
    use crate::cluster::Machine;
    use crate::metrics::ScalingAction;
    use crate::pilot::SparkDescription;

    fn wait_until(mut cond: impl FnMut() -> bool, secs: f64) -> bool {
        let t0 = Instant::now();
        while t0.elapsed().as_secs_f64() < secs {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        false
    }

    #[test]
    fn controller_extends_on_lag_and_shrinks_after_drain() {
        let service = Arc::new(PilotComputeService::new(Machine::unthrottled(5)));
        let (kafka, cluster) = service
            .start_kafka(crate::pilot::KafkaDescription::new(1))
            .unwrap();
        let (spark, engine) = service
            .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
            .unwrap();
        cluster.create_topic("load", 2).unwrap();

        let policy = ThresholdPolicy::new(10, 1)
            .with_sustain(1)
            .with_cooldown_secs(0.1)
            .with_step(2);
        let scaler = Autoscaler::spawn(
            service.clone(),
            spark.clone(),
            cluster.clone(),
            None,
            Box::new(policy),
            AutoscalerConfig::new("load", "g")
                .with_sample_interval(Duration::from_millis(20))
                .with_max_extension_nodes(2)
                .with_max_step(2),
        );

        // Backpressure: 40 uncommitted messages.
        for i in 0..40u8 {
            cluster.produce("load", (i % 2) as usize, 0, &[vec![i]]).unwrap();
        }
        assert!(
            wait_until(|| scaler.extension_count() == 1, 5.0),
            "no scale-up within 5s"
        );
        assert_eq!(engine.executor_count(), 3, "1 base + 2 extension nodes");

        // Drain: commit everything; the policy must scale back down.
        cluster.commit("g", "load", 0, 20);
        cluster.commit("g", "load", 1, 20);
        assert!(
            wait_until(|| scaler.extension_count() == 0, 5.0),
            "no scale-down within 5s"
        );

        let remaining = scaler.stop();
        assert!(remaining.is_empty());
        // 5 - kafka(1) - spark(1): extension nodes back in the pool.
        assert_eq!(service.machine().free_nodes(), 3);
        service.stop_pilot(&spark).unwrap();
        service.stop_pilot(&kafka).unwrap();
    }

    #[test]
    fn controller_repartitions_before_extending_past_the_cap() {
        use crate::autoscale::policy::PartitionElastic;

        let service = Arc::new(PilotComputeService::new(Machine::unthrottled(5)));
        let (kafka, cluster) = service
            .start_kafka(crate::pilot::KafkaDescription::new(1))
            .unwrap();
        let (spark, _engine) = service
            .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
            .unwrap();
        cluster.create_topic("capped", 1).unwrap();

        let inner = ThresholdPolicy::new(10, 1)
            .with_sustain(1)
            .with_cooldown_secs(0.1)
            .with_step(2);
        let scaler = Autoscaler::spawn(
            service.clone(),
            spark.clone(),
            cluster.clone(),
            None,
            Box::new(PartitionElastic::new(inner, 1)),
            AutoscalerConfig::new("capped", "g")
                .with_sample_interval(Duration::from_millis(20))
                .with_max_extension_nodes(2)
                .with_max_step(2),
        );
        // Standing lag on the single partition: the wrapped policy must
        // repartition to 3 (1 base + 2 extension slots) and extend.
        let batch: Vec<Vec<u8>> = (0..20u8).map(|i| vec![i]).collect();
        cluster.produce("capped", 0, 0, &batch).unwrap();

        let timeline = scaler.timeline();
        assert!(
            wait_until(|| timeline.count(ScalingAction::Repartition) >= 1, 5.0),
            "no repartition event"
        );
        assert!(
            wait_until(|| scaler.extension_count() >= 1, 5.0),
            "no extension after repartition"
        );
        assert_eq!(cluster.partition_count("capped").unwrap(), 3);
        let events = timeline.events();
        let rp = events
            .iter()
            .position(|e| e.action == ScalingAction::Repartition)
            .unwrap();
        let up = events.iter().position(|e| e.action == ScalingAction::Up).unwrap();
        assert!(rp < up, "repartition must precede the extension");
        assert_eq!(events[rp].partitions, 3);
        assert_eq!(events[rp].policy, "partition-elastic");

        for p in scaler.stop() {
            service.stop_pilot(&p).unwrap();
        }
        service.stop_pilot(&spark).unwrap();
        service.stop_pilot(&kafka).unwrap();
    }

    #[test]
    fn timeline_records_up_then_down_with_reaction_latency() {
        let service = Arc::new(PilotComputeService::new(Machine::unthrottled(4)));
        let (kafka, cluster) = service
            .start_kafka(crate::pilot::KafkaDescription::new(1))
            .unwrap();
        let (spark, _engine) = service
            .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
            .unwrap();
        cluster.create_topic("t", 1).unwrap();

        let policy = ThresholdPolicy::new(5, 0)
            .with_sustain(1)
            .with_cooldown_secs(0.05);
        let scaler = Autoscaler::spawn(
            service.clone(),
            spark.clone(),
            cluster.clone(),
            None,
            Box::new(policy),
            AutoscalerConfig::new("t", "g")
                .with_sample_interval(Duration::from_millis(20))
                .with_max_extension_nodes(1),
        );
        let batch: Vec<Vec<u8>> = (0..8u8).map(|i| vec![i]).collect();
        cluster.produce("t", 0, 0, &batch).unwrap();
        let timeline = scaler.timeline();
        assert!(
            wait_until(|| timeline.count(ScalingAction::Up) >= 1, 5.0),
            "no Up event"
        );
        cluster.commit("g", "t", 0, 8);
        assert!(
            wait_until(|| timeline.count(ScalingAction::Down) >= 1, 5.0),
            "no Down event"
        );
        for p in scaler.stop() {
            let _ = service.stop_pilot(&p);
        }
        let events = timeline.events();
        let up = events.iter().find(|e| e.action == ScalingAction::Up).unwrap();
        assert!(up.reaction_secs >= 0.0);
        assert_eq!(up.delta_nodes, 1);
        assert_eq!(up.policy, "threshold");
        assert!(up.lag >= 5);
        service.stop_pilot(&spark).unwrap();
        service.stop_pilot(&kafka).unwrap();
    }
}
