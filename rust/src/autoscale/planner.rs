//! The scaling planner: costed, multi-step plans from policy intents.
//!
//! Policies answer *what* they want ([`ScalingIntent`]); the planner
//! answers *whether it is worth it and what it actually takes*.  It is
//! the stage the reactive-controller literature calls planning (de
//! Assunção et al. 2017): between decision and actuation, weigh each
//! action's cost against its expected benefit, and expand one intent
//! into the multi-step plan that makes the action safe across tiers.
//!
//! Two cost inputs drive it:
//!
//! * **Per-framework extension costs** — from
//!   [`crate::plugins::extension_cost_secs`] (the same model the pilot
//!   service records for real extensions): a Kafka broker join +
//!   rebalance is ~4x a Dask worker join, so the same lag justifies
//!   different actions on different tiers.  A scale-up whose extension
//!   lead time cannot pay for itself within the drain horizon is
//!   *deferred*; one that over-buys drain capacity is *resized* down to
//!   the smallest step that covers the projected backlog.
//! * **Broker-tier saturation** — the per-node NIC/disk token-bucket
//!   gauges on the [`SignalSnapshot`].  A repartition whose new
//!   partition count would oversubscribe the per-node I/O budget
//!   co-schedules a broker-extension step in the same plan (the
//!   ROADMAP's repartition-aware broker scale-up), and a processing
//!   scale-up issued while the broker tier is saturated brings a broker
//!   node along — otherwise the new executors would just move the
//!   bottleneck.
//!
//! Plans are pure data: the [`super::Autoscaler`] executes them step by
//! step on the real plane, and [`crate::sim::ElasticSim::run_planned`]
//! executes them in virtual time, so the same cost reasoning is
//! testable deterministically at 32-node scale.

use crate::pilot::FrameworkKind;
use crate::plugins::extension_cost_secs;

use super::policy::ScalingIntent;
use super::signals::SignalSnapshot;

/// Modeled cost of one plan step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepCost {
    /// Seconds until the step's capacity is usable (framework extension
    /// lead time; epoch drain for repartitions).
    pub lead_secs: f64,
    /// Node-seconds committed before the capacity earns anything
    /// (`nodes * lead_secs`; 0 for repartitions).
    pub node_secs: f64,
}

impl StepCost {
    pub fn zero() -> Self {
        StepCost { lead_secs: 0.0, node_secs: 0.0 }
    }
}

/// One step of a [`ScalingPlan`], in execution order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PlanStep {
    /// Extend the broker-tier pilot by `nodes` nodes.
    ExtendBroker { nodes: usize, cost: StepCost },
    /// Repartition the watched topic to `partitions` partitions.
    Repartition { partitions: usize, cost: StepCost },
    /// Extend the processing-tier pilot by `nodes` nodes.
    ExtendProcessing { nodes: usize, cost: StepCost },
    /// Release `nodes` processing nodes (stop extension pilots).
    ShrinkProcessing { nodes: usize },
    /// Move up to `moves` follower replicas off hot or rack-crowded
    /// brokers ([`crate::broker::BrokerCluster::reassign_replicas`]).
    /// Re-places existing replicas on the existing tier — no new
    /// nodes, so its cost is a short lead and zero node-seconds; the
    /// cheap alternative the planner prefers over a broker extension
    /// when capacity is fine but *placement* is not.
    ReassignReplicas { moves: usize, cost: StepCost },
}

/// Why a plan was deferred instead of actuated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeferReason {
    /// The extension's lead time exceeds the drain horizon: the new
    /// nodes could never pay for themselves before the horizon closes.
    LeadBeyondHorizon,
    /// The current fleet already drains the projected backlog within
    /// the horizon; buying more capacity would be pure cost.
    FleetSufficient,
}

/// A costed, ordered sequence of scaling steps produced from one
/// [`ScalingIntent`].  Empty `steps` with `deferred: None` is a hold.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalingPlan {
    pub steps: Vec<PlanStep>,
    /// Messages the plan is expected to drain within the horizon
    /// (beyond what the current fleet would; 0 when uncalibrated).
    pub expected_drain_msgs: f64,
    /// Why the planner declined to act, if it did.
    pub deferred: Option<DeferReason>,
}

impl ScalingPlan {
    pub fn hold() -> Self {
        ScalingPlan { steps: Vec::new(), expected_drain_msgs: 0.0, deferred: None }
    }

    pub fn deferred(reason: DeferReason) -> Self {
        ScalingPlan { steps: Vec::new(), expected_drain_msgs: 0.0, deferred: Some(reason) }
    }

    pub fn is_hold(&self) -> bool {
        self.steps.is_empty() && self.deferred.is_none()
    }

    /// Processing nodes this plan adds.
    pub fn added_processing_nodes(&self) -> usize {
        self.steps
            .iter()
            .map(|s| match s {
                PlanStep::ExtendProcessing { nodes, .. } => *nodes,
                _ => 0,
            })
            .sum()
    }

    /// Broker nodes this plan adds.
    pub fn added_broker_nodes(&self) -> usize {
        self.steps
            .iter()
            .map(|s| match s {
                PlanStep::ExtendBroker { nodes, .. } => *nodes,
                _ => 0,
            })
            .sum()
    }

    /// The partition count this plan repartitions to, if any.
    pub fn repartition_target(&self) -> Option<usize> {
        self.steps.iter().find_map(|s| match s {
            PlanStep::Repartition { partitions, .. } => Some(*partitions),
            _ => None,
        })
    }

    /// Longest lead among the plan's steps (steps run co-scheduled, so
    /// the plan is "paid off" once the slowest step lands).
    pub fn total_lead_secs(&self) -> f64 {
        self.steps
            .iter()
            .map(|s| match s {
                PlanStep::ExtendBroker { cost, .. }
                | PlanStep::Repartition { cost, .. }
                | PlanStep::ExtendProcessing { cost, .. }
                | PlanStep::ReassignReplicas { cost, .. } => cost.lead_secs,
                PlanStep::ShrinkProcessing { .. } => 0.0,
            })
            .fold(0.0, f64::max)
    }
}

/// Planner tuning.  The controller derives `max_step` from its
/// [`super::AutoscalerConfig`] and the frameworks from the target
/// pilots, so plans can never exceed what the controller may actuate.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// Framework of the processing tier (extension cost model).
    pub processing_framework: FrameworkKind,
    /// Framework of the broker tier (extension cost model).
    pub broker_framework: FrameworkKind,
    /// Largest processing extension a single plan may request.
    pub max_step: usize,
    /// Horizon within which a scale-up must pay for itself: the drain
    /// benefit is counted only over `horizon - lead` seconds.  Keep it
    /// generous (default 600 s) unless deferral is the point.
    pub drain_horizon_secs: f64,
    /// Per-node I/O budget: partitions one broker node can serve before
    /// its NIC/disk token buckets oversubscribe (paper: 12).
    pub partitions_per_broker_node: usize,
    /// Peak per-node NIC/disk utilization beyond which a processing
    /// scale-up co-schedules a broker node.
    pub broker_util_threshold: f64,
    /// Largest broker extension a single plan may co-schedule (0
    /// disables broker co-scheduling entirely).
    pub max_broker_step: usize,
    /// Broker-tier load imbalance (`SignalSnapshot::broker_util_skew`,
    /// peak minus mean per-node utilization) beyond which a Hold turns
    /// into a replica-reassignment step.  Placement repair is not
    /// gated by `max_broker_step`: it moves replicas on the tier the
    /// cluster already has.
    pub broker_skew_threshold: f64,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            processing_framework: FrameworkKind::Spark,
            broker_framework: FrameworkKind::Kafka,
            max_step: 4,
            drain_horizon_secs: 600.0,
            partitions_per_broker_node: 12,
            broker_util_threshold: 0.85,
            max_broker_step: 2,
            broker_skew_threshold: 0.5,
        }
    }
}

impl PlannerConfig {
    pub fn with_frameworks(mut self, processing: FrameworkKind, broker: FrameworkKind) -> Self {
        self.processing_framework = processing;
        self.broker_framework = broker;
        self
    }

    pub fn with_max_step(mut self, nodes: usize) -> Self {
        self.max_step = nodes.max(1);
        self
    }

    pub fn with_drain_horizon_secs(mut self, secs: f64) -> Self {
        self.drain_horizon_secs = secs.max(1e-3);
        self
    }

    pub fn with_partitions_per_broker_node(mut self, partitions: usize) -> Self {
        self.partitions_per_broker_node = partitions.max(1);
        self
    }

    pub fn with_broker_util_threshold(mut self, threshold: f64) -> Self {
        self.broker_util_threshold = threshold.clamp(0.05, 1.0);
        self
    }

    pub fn with_max_broker_step(mut self, nodes: usize) -> Self {
        self.max_broker_step = nodes;
        self
    }

    pub fn with_broker_skew_threshold(mut self, threshold: f64) -> Self {
        self.broker_skew_threshold = threshold.clamp(0.05, 1.0);
        self
    }
}

/// Stateless intent → plan translator (same inputs, same plan — the
/// virtual-time determinism the sim harness pins relies on this).
#[derive(Debug, Clone)]
pub struct Planner {
    config: PlannerConfig,
}

impl Planner {
    pub fn new(config: PlannerConfig) -> Self {
        Planner { config }
    }

    pub fn config(&self) -> &PlannerConfig {
        &self.config
    }

    /// Extension cost of `nodes` processing/broker nodes.
    fn extend_cost(&self, kind: FrameworkKind, nodes: usize) -> StepCost {
        let lead_secs = extension_cost_secs(kind, nodes);
        StepCost { lead_secs, node_secs: nodes as f64 * lead_secs }
    }

    /// Turn one policy intent into a costed plan for this snapshot.
    pub fn plan(&self, intent: ScalingIntent, s: &SignalSnapshot) -> ScalingPlan {
        match intent {
            ScalingIntent::Hold => self.plan_replication_repair(s),
            ScalingIntent::ScaleDown(n) => {
                let n = n.min(s.nodes.saturating_sub(s.min_nodes));
                if n == 0 {
                    return ScalingPlan::hold();
                }
                ScalingPlan {
                    steps: vec![PlanStep::ShrinkProcessing { nodes: n }],
                    expected_drain_msgs: 0.0,
                    deferred: None,
                }
            }
            ScalingIntent::ScaleUp(n) => self.plan_growth(n, None, s),
            ScalingIntent::Repartition { partitions, scale_up } => {
                self.plan_growth(scale_up, Some(partitions), s)
            }
        }
    }

    /// Quorum-degraded replication is a first-class scaling signal: a
    /// Hold intent (lag is fine) still becomes a broker-replacement
    /// plan while partitions run with an ISR below their topic's
    /// `min_insync` — those partitions reject `AckMode::Quorum`
    /// produces until the tier heals, so waiting for lag to show the
    /// damage is waiting too long.  Mere under-replication (replicas
    /// below factor but quorum still healthy) deliberately does *not*
    /// trigger repair: durability headroom is reduced, availability is
    /// not.  One replacement node per plan:
    /// `BrokerCluster::add_brokers` reassigns every degraded replica
    /// set as soon as the node lands, and the next probe re-plans if
    /// the tier lost more than one node.
    /// Placement debt — rack-crowded replica sets or one hot broker
    /// next to idle peers — also turns a Hold into action, but the
    /// *cheap* kind: a [`PlanStep::ReassignReplicas`] that re-places
    /// follower replicas on the tier the cluster already has, instead
    /// of buying a node.  Availability repair always wins when both
    /// fire: reassignment is pointless while quorum is down.
    fn plan_replication_repair(&self, s: &SignalSnapshot) -> ScalingPlan {
        if s.below_min_insync > 0 && self.config.max_broker_step > 0 {
            return ScalingPlan {
                steps: vec![PlanStep::ExtendBroker {
                    nodes: 1,
                    cost: self.extend_cost(self.config.broker_framework, 1),
                }],
                expected_drain_msgs: 0.0,
                deferred: None,
            };
        }
        if s.below_min_insync == 0
            && s.broker_nodes > 1
            && (s.rack_skew > 0.0 || s.broker_util_skew >= self.config.broker_skew_threshold)
        {
            // Size the pass by the crowding it must undo (at least one
            // move for a pure load-skew trigger).  Moving a replica is
            // a metadata edit plus a catch-up stream — a short lead,
            // no committed node-seconds.
            let moves = ((s.partitions as f64 * s.rack_skew).ceil() as usize).max(1);
            return ScalingPlan {
                steps: vec![PlanStep::ReassignReplicas {
                    moves,
                    cost: StepCost {
                        lead_secs: (moves as f64 * 0.5).max(1.0),
                        node_secs: 0.0,
                    },
                }],
                expected_drain_msgs: 0.0,
                deferred: None,
            };
        }
        ScalingPlan::hold()
    }

    /// Drain benefit of `k` extra nodes within the horizon: the extra
    /// service the new nodes provide once their extension lands.
    fn benefit_msgs(&self, k: usize, rate_per_node: f64) -> f64 {
        let lead = extension_cost_secs(self.config.processing_framework, k);
        k as f64 * rate_per_node * (self.config.drain_horizon_secs - lead).max(0.0)
    }

    fn plan_growth(
        &self,
        scale_up: usize,
        repartition: Option<usize>,
        s: &SignalSnapshot,
    ) -> ScalingPlan {
        let headroom = s.max_nodes.saturating_sub(s.nodes);
        let requested = scale_up.min(self.config.max_step).min(headroom);
        let mut n = requested;
        if n == 0 {
            // Nothing can be added (ceiling reached).  Growing the
            // partition count anyway would inflate every cooldown with
            // nothing new to consume it, so the whole plan holds —
            // mirroring the pre-planner controller guard.
            return ScalingPlan::hold();
        }

        // Cost/benefit gate — only once the service rate is calibrated
        // (rate 0 means no consumption observed yet; acting on lag is
        // all we can do, so the intent passes through uncosted).
        let rate = s.service_rate_per_node;
        let mut expected_drain = 0.0;
        if rate > 0.0 {
            let h = self.config.drain_horizon_secs;
            // Backlog at the horizon if the fleet stays as-is: the lag
            // slope already nets out current consumption.
            let projected = (s.lag as f64 + s.lag_slope * h).max(0.0);
            if projected <= 0.0 {
                return ScalingPlan::deferred(DeferReason::FleetSufficient);
            }
            // A large extension may be unpayable only because of its
            // extra launch waves: shrink until the lead fits the
            // horizon before concluding nothing can pay.
            while n > 1 && self.benefit_msgs(n, rate) <= 0.0 {
                n -= 1;
            }
            if self.benefit_msgs(n, rate) <= 0.0 {
                return ScalingPlan::deferred(DeferReason::LeadBeyondHorizon);
            }
            // Resize: the smallest step whose drain benefit covers the
            // projected backlog (buying more would be idle footprint);
            // keep the full request when even it cannot cover.
            for k in 1..n {
                if self.benefit_msgs(k, rate) >= projected {
                    n = k;
                    break;
                }
            }
            expected_drain = self.benefit_msgs(n, rate).min(projected);
        }

        // A repartition target sized for the policy's full request must
        // shrink with a right-sized step: buying partitions (and the
        // broker nodes to serve them) that the smaller fleet cannot
        // consume is exactly the over-provisioning this planner exists
        // to prevent.  Scale proportionally to the fleet the plan
        // actually builds; if that leaves nothing to grow, the
        // repartition drops out below.
        let repartition = repartition.map(|p| {
            if n < requested {
                let scaled = (p as f64 * (s.nodes + n) as f64 / (s.nodes + requested) as f64)
                    .ceil() as usize;
                scaled.max(1)
            } else {
                p
            }
        });

        let mut steps = Vec::new();
        let budget = self.config.partitions_per_broker_node.max(1);
        match repartition {
            Some(p) => {
                let mut target = p;
                let capacity_now = s.broker_nodes * budget;
                let mut broker_added = 0;
                if target > capacity_now {
                    // Oversubscribed per-node I/O budgets: co-schedule
                    // a broker extension sized for the new partition
                    // count, then clamp the partition count to what the
                    // extended tier can actually serve.
                    let needed = target.div_ceil(budget).saturating_sub(s.broker_nodes);
                    broker_added = needed.min(self.config.max_broker_step);
                    if broker_added > 0 {
                        steps.push(PlanStep::ExtendBroker {
                            nodes: broker_added,
                            cost: self.extend_cost(self.config.broker_framework, broker_added),
                        });
                    }
                    target = target.min((s.broker_nodes + broker_added) * budget);
                }
                // A target clamped at or below the current count is a
                // no-op (never a shrink-by-accident); deliberate
                // resizes (p within budget) pass through untouched.
                if target != s.partitions && (target == p || target > s.partitions) {
                    steps.push(PlanStep::Repartition {
                        partitions: target,
                        cost: StepCost { lead_secs: s.window_secs.max(0.0), node_secs: 0.0 },
                    });
                }
            }
            None => {
                // No repartition in the intent, but a saturated broker
                // tier still travels with the scale-up: new executors
                // behind a saturated broker just move the bottleneck.
                // Quorum-degraded replication rides along the same way
                // — the replacement node heals the replica sets the
                // moment `add_brokers` lands it.
                let util = s.broker_nic_util.max(s.broker_disk_util);
                let degraded = s.below_min_insync > 0;
                if (util >= self.config.broker_util_threshold || degraded)
                    && self.config.max_broker_step > 0
                {
                    steps.push(PlanStep::ExtendBroker {
                        nodes: 1,
                        cost: self.extend_cost(self.config.broker_framework, 1),
                    });
                }
            }
        }
        steps.push(PlanStep::ExtendProcessing {
            nodes: n,
            cost: self.extend_cost(self.config.processing_framework, n),
        });
        ScalingPlan { steps, expected_drain_msgs: expected_drain, deferred: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(lag: u64, nodes: usize) -> SignalSnapshot {
        SignalSnapshot {
            t_secs: 10.0,
            lag,
            lag_slope: 0.0,
            produce_rate: 0.0,
            consume_rate: 0.0,
            partition_backlog: Vec::new(),
            partitions: 8,
            behind_batches: 0,
            last_batch_secs: 0.0,
            window_secs: 1.0,
            nodes,
            min_nodes: 1,
            max_nodes: 16,
            service_rate_per_node: 0.0,
            broker_nodes: 2,
            broker_nic_util: 0.0,
            broker_disk_util: 0.0,
            under_replicated: 0,
            below_min_insync: 0,
            broker_util_skew: 0.0,
            rack_skew: 0.0,
            shard_queue_depths: Vec::new(),
            edge_lags: Vec::new(),
        }
    }

    fn planner() -> Planner {
        Planner::new(PlannerConfig::default().with_max_step(8))
    }

    #[test]
    fn hold_and_shrink_pass_through() {
        let p = planner();
        assert!(p.plan(ScalingIntent::Hold, &snap(0, 4)).is_hold());
        let plan = p.plan(ScalingIntent::ScaleDown(2), &snap(0, 4));
        assert_eq!(plan.steps, vec![PlanStep::ShrinkProcessing { nodes: 2 }]);
        // Clamped to the fleet floor; a no-op shrink is a hold.
        let plan = p.plan(ScalingIntent::ScaleDown(9), &snap(0, 4));
        assert_eq!(plan.steps, vec![PlanStep::ShrinkProcessing { nodes: 3 }]);
        assert!(p.plan(ScalingIntent::ScaleDown(2), &snap(0, 1)).is_hold());
    }

    #[test]
    fn uncalibrated_scale_up_passes_through_with_costs() {
        let p = planner();
        let plan = p.plan(ScalingIntent::ScaleUp(2), &snap(500, 4));
        assert_eq!(plan.added_processing_nodes(), 2);
        assert_eq!(plan.deferred, None);
        let PlanStep::ExtendProcessing { cost, .. } = plan.steps[0] else {
            panic!("expected processing step, got {:?}", plan.steps);
        };
        // Spark: one wave of 2 nodes (6 s) + settle (10 s).
        assert_eq!(cost.lead_secs, 16.0);
        assert_eq!(cost.node_secs, 32.0);
    }

    #[test]
    fn scale_up_clamps_to_max_step_and_ceiling() {
        let p = planner();
        let plan = p.plan(ScalingIntent::ScaleUp(50), &snap(500, 4));
        assert_eq!(plan.added_processing_nodes(), 8, "max_step clamp");
        let plan = p.plan(ScalingIntent::ScaleUp(50), &snap(500, 14));
        assert_eq!(plan.added_processing_nodes(), 2, "ceiling clamp");
        assert!(p.plan(ScalingIntent::ScaleUp(3), &snap(500, 16)).is_hold());
    }

    #[test]
    fn costed_scale_up_resizes_to_cover_projected_backlog() {
        let p = planner();
        let mut s = snap(5_000, 2);
        s.service_rate_per_node = 10.0;
        // Spark lead 16 s, horizon 600 s: one node drains 5 840 msgs >
        // the 5 000 projected, so an 8-node request resizes to 1.
        let plan = p.plan(ScalingIntent::ScaleUp(8), &s);
        assert_eq!(plan.added_processing_nodes(), 1);
        assert!(plan.expected_drain_msgs > 0.0);
        // A much larger backlog keeps the full request.
        let mut s = snap(5_000_000, 2);
        s.service_rate_per_node = 10.0;
        let plan = p.plan(ScalingIntent::ScaleUp(8), &s);
        assert_eq!(plan.added_processing_nodes(), 8);
    }

    #[test]
    fn scale_up_deferred_when_fleet_drains_within_horizon() {
        let p = planner();
        let mut s = snap(1_000, 4);
        s.service_rate_per_node = 10.0;
        s.lag_slope = -20.0; // draining fast: gone well inside 600 s
        let plan = p.plan(ScalingIntent::ScaleUp(2), &s);
        assert_eq!(plan.deferred, Some(DeferReason::FleetSufficient));
        assert!(plan.steps.is_empty());
    }

    #[test]
    fn scale_up_deferred_when_lead_exceeds_horizon() {
        let p = Planner::new(
            PlannerConfig::default().with_max_step(8).with_drain_horizon_secs(10.0),
        );
        let mut s = snap(100_000, 2);
        s.service_rate_per_node = 10.0;
        // Spark lead is 16 s even for one node > 10 s horizon: no step
        // size can pay for itself before the horizon closes.
        let plan = p.plan(ScalingIntent::ScaleUp(2), &s);
        assert_eq!(plan.deferred, Some(DeferReason::LeadBeyondHorizon));
    }

    #[test]
    fn unpayable_large_step_shrinks_to_payable_size_instead_of_deferring() {
        // Horizon 30 s: 8 Spark nodes take 4 waves (34 s lead, can't
        // pay) but 2 nodes take one wave (16 s lead, pays).  The plan
        // must resize, not defer.
        let p = Planner::new(
            PlannerConfig::default().with_max_step(8).with_drain_horizon_secs(30.0),
        );
        let mut s = snap(10_000_000, 2);
        s.service_rate_per_node = 10.0;
        let plan = p.plan(ScalingIntent::ScaleUp(8), &s);
        assert_eq!(plan.deferred, None);
        let up = plan.added_processing_nodes();
        assert!((1..8).contains(&up), "expected a right-sized step, got {up}");
        assert!(plan.expected_drain_msgs > 0.0);
    }

    #[test]
    fn resized_step_right_sizes_the_repartition_ask() {
        let p = planner();
        let mut s = snap(5_000, 2);
        s.service_rate_per_node = 10.0;
        s.partitions = 2;
        s.broker_nodes = 2;
        // The 8-node request resizes to 1 (one node's drain covers the
        // 5 000 projected messages), so the partition ask shrinks with
        // the fleet it actually builds: ceil(20 * (2+1)/(2+8)) = 6,
        // not the 20 the policy sized for 8 new nodes.
        let plan = p.plan(ScalingIntent::Repartition { partitions: 20, scale_up: 8 }, &s);
        assert_eq!(plan.added_processing_nodes(), 1);
        assert_eq!(plan.repartition_target(), Some(6));
        assert_eq!(plan.added_broker_nodes(), 0, "6 partitions fit the 2-broker budget");
    }

    #[test]
    fn repartition_within_budget_has_no_broker_step() {
        let p = planner();
        let mut s = snap(500, 2);
        s.partitions = 8;
        s.broker_nodes = 2; // budget 24 partitions
        let plan = p.plan(ScalingIntent::Repartition { partitions: 12, scale_up: 2 }, &s);
        assert_eq!(plan.added_broker_nodes(), 0);
        assert_eq!(plan.repartition_target(), Some(12));
        assert_eq!(plan.added_processing_nodes(), 2);
        // Repartition step precedes the processing extension.
        assert!(matches!(plan.steps[0], PlanStep::Repartition { .. }));
        assert!(matches!(plan.steps[1], PlanStep::ExtendProcessing { .. }));
    }

    #[test]
    fn oversubscribing_repartition_coschedules_broker_extension() {
        let p = planner();
        let mut s = snap(500, 2);
        s.partitions = 24;
        s.broker_nodes = 2; // budget 24: already full
        let plan = p.plan(ScalingIntent::Repartition { partitions: 40, scale_up: 4 }, &s);
        // 40 partitions need ceil(40/12) = 4 brokers -> +2.
        assert_eq!(plan.added_broker_nodes(), 2);
        assert_eq!(plan.repartition_target(), Some(40));
        assert!(matches!(plan.steps[0], PlanStep::ExtendBroker { .. }));
        assert!(matches!(plan.steps[1], PlanStep::Repartition { .. }));
        assert!(matches!(plan.steps[2], PlanStep::ExtendProcessing { .. }));
        let PlanStep::ExtendBroker { cost, .. } = plan.steps[0] else { unreachable!() };
        // Kafka: one wave of 2 nodes (8 s) + rebalance settle (15 s).
        assert_eq!(cost.lead_secs, 23.0);
        // Steps run co-scheduled, so the plan pays off once its slowest
        // step lands — the broker join here.
        assert_eq!(plan.total_lead_secs(), 23.0);
    }

    #[test]
    fn repartition_clamps_partitions_to_broker_step_budget() {
        let p = Planner::new(PlannerConfig::default().with_max_step(8).with_max_broker_step(1));
        let mut s = snap(500, 2);
        s.partitions = 24;
        s.broker_nodes = 2;
        // 80 partitions would need 7 brokers; only 1 can be added, so
        // the partition target clamps to (2+1)*12 = 36.
        let plan = p.plan(ScalingIntent::Repartition { partitions: 80, scale_up: 4 }, &s);
        assert_eq!(plan.added_broker_nodes(), 1);
        assert_eq!(plan.repartition_target(), Some(36));
    }

    #[test]
    fn saturated_broker_tier_travels_with_plain_scale_up() {
        let p = planner();
        let mut s = snap(500, 2);
        s.broker_nic_util = 0.95;
        let plan = p.plan(ScalingIntent::ScaleUp(2), &s);
        assert_eq!(plan.added_broker_nodes(), 1);
        assert!(matches!(plan.steps[0], PlanStep::ExtendBroker { .. }));
        // Below threshold: no broker step.
        s.broker_nic_util = 0.5;
        let plan = p.plan(ScalingIntent::ScaleUp(2), &s);
        assert_eq!(plan.added_broker_nodes(), 0);
    }

    #[test]
    fn degraded_replication_turns_hold_into_broker_replacement() {
        let p = planner();
        let mut s = snap(0, 4);
        s.under_replicated = 3;
        s.below_min_insync = 3;
        let plan = p.plan(ScalingIntent::Hold, &s);
        assert_eq!(plan.added_broker_nodes(), 1, "one replacement node");
        assert_eq!(plan.added_processing_nodes(), 0);
        let PlanStep::ExtendBroker { cost, .. } = plan.steps[0] else {
            panic!("expected broker step, got {:?}", plan.steps);
        };
        // Kafka: one wave of 1 node (8 s) + rebalance settle (15 s).
        assert_eq!(cost.lead_secs, 23.0);
        // With co-scheduling disabled the planner cannot buy brokers.
        let p0 = Planner::new(PlannerConfig::default().with_max_broker_step(0));
        assert!(p0.plan(ScalingIntent::Hold, &s).is_hold());
        // A healthy tier holds a Hold.
        s.under_replicated = 0;
        s.below_min_insync = 0;
        assert!(p.plan(ScalingIntent::Hold, &s).is_hold());
    }

    #[test]
    fn under_replicated_but_quorum_healthy_does_not_repair() {
        // The pre-split signal conflated "replicas < factor" with
        // "quorum degraded": a factor-3/min_insync-2 topic with one
        // dead follower triggered broker repair even though quorum was
        // healthy.  Only `below_min_insync` may buy a node on Hold.
        let p = planner();
        let mut s = snap(0, 4);
        s.under_replicated = 3;
        s.below_min_insync = 0;
        assert!(p.plan(ScalingIntent::Hold, &s).is_hold());
        // And it does not ride along a scale-up either.
        let mut s = snap(500, 2);
        s.under_replicated = 2;
        let plan = p.plan(ScalingIntent::ScaleUp(2), &s);
        assert_eq!(plan.added_broker_nodes(), 0);
    }

    #[test]
    fn degraded_replication_rides_along_a_scale_up() {
        let p = planner();
        let mut s = snap(500, 2);
        s.under_replicated = 2;
        s.below_min_insync = 2;
        // Broker tier far from saturated — the replacement still rides.
        let plan = p.plan(ScalingIntent::ScaleUp(2), &s);
        assert_eq!(plan.added_broker_nodes(), 1);
        assert!(matches!(plan.steps[0], PlanStep::ExtendBroker { .. }));
        assert_eq!(plan.added_processing_nodes(), 2);
    }

    #[test]
    fn rack_skew_turns_hold_into_reassignment_not_extension() {
        let p = planner();
        let mut s = snap(0, 4);
        s.rack_skew = 1.0; // every replicated partition crowded
        let plan = p.plan(ScalingIntent::Hold, &s);
        assert_eq!(plan.added_broker_nodes(), 0, "placement repair buys no nodes");
        assert_eq!(plan.added_processing_nodes(), 0);
        let PlanStep::ReassignReplicas { moves, cost } = plan.steps[0] else {
            panic!("expected reassignment step, got {:?}", plan.steps);
        };
        assert_eq!(moves, 8, "one move per crowded partition (8 partitions x skew 1.0)");
        assert_eq!(cost.lead_secs, 4.0);
        assert_eq!(cost.node_secs, 0.0, "no committed node-seconds");
        assert_eq!(plan.total_lead_secs(), 4.0);
        // Not gated by max_broker_step: reassignment never buys nodes.
        let p0 = Planner::new(PlannerConfig::default().with_max_broker_step(0));
        let plan = p0.plan(ScalingIntent::Hold, &s);
        assert!(matches!(plan.steps[0], PlanStep::ReassignReplicas { .. }));
        // A single-broker tier has nowhere to move replicas.
        s.broker_nodes = 1;
        assert!(p.plan(ScalingIntent::Hold, &s).is_hold());
    }

    #[test]
    fn hot_broker_skew_triggers_reassignment_below_repair_above_hold() {
        let p = planner();
        let mut s = snap(0, 4);
        s.broker_util_skew = 0.6; // default threshold 0.5
        let plan = p.plan(ScalingIntent::Hold, &s);
        let PlanStep::ReassignReplicas { moves, .. } = plan.steps[0] else {
            panic!("expected reassignment step, got {:?}", plan.steps);
        };
        assert_eq!(moves, 1, "pure load skew sizes a minimal pass");
        // Below the threshold, a Hold stays a hold.
        s.broker_util_skew = 0.4;
        assert!(p.plan(ScalingIntent::Hold, &s).is_hold());
        // A raised threshold is honored.
        let strict =
            Planner::new(PlannerConfig::default().with_broker_skew_threshold(0.9));
        s.broker_util_skew = 0.6;
        assert!(strict.plan(ScalingIntent::Hold, &s).is_hold());
    }

    #[test]
    fn availability_repair_outranks_placement_repair() {
        // Quorum down AND placement crowded: the replacement broker
        // wins — reassignment is pointless while produces are rejected.
        let p = planner();
        let mut s = snap(0, 4);
        s.below_min_insync = 2;
        s.rack_skew = 1.0;
        let plan = p.plan(ScalingIntent::Hold, &s);
        assert_eq!(plan.added_broker_nodes(), 1);
        assert!(matches!(plan.steps[0], PlanStep::ExtendBroker { .. }));
        assert!(!plan.steps.iter().any(|st| matches!(st, PlanStep::ReassignReplicas { .. })));
    }

    #[test]
    fn plans_are_deterministic() {
        let p = planner();
        let mut s = snap(123_456, 3);
        s.service_rate_per_node = 7.5;
        s.lag_slope = 42.0;
        s.broker_nic_util = 0.9;
        let intent = ScalingIntent::Repartition { partitions: 60, scale_up: 5 };
        assert_eq!(p.plan(intent, &s), p.plan(intent, &s));
    }
}
