//! The Pilot abstraction (paper §4): descriptions, state machine,
//! plugin SPI and the Pilot-Compute service.
//!
//! A Pilot-Job is "a placeholder job providing multi-level scheduling
//! ... application-level control over the system scheduler" [P* model].
//! Pilot-Streaming extends it to provision *frameworks* (Kafka, Spark,
//! Dask, Flink) inside the placeholder allocation and to scale them at
//! runtime by chaining additional pilots to a parent (paper Listing 4).

pub mod description;
pub mod plugin;
pub mod service;
pub mod state;

pub use description::{
    DaskDescription, FlinkDescription, FrameworkKind, KafkaDescription, PilotComputeDescription,
    SparkDescription,
};
pub use plugin::{FrameworkContext, ManagerPlugin, PluginEnv};
pub use service::{
    Pilot, PilotComputeService, PilotEventKind, PilotScalingEvent, ScalingHook, StartupBreakdown,
};
pub use state::PilotState;
