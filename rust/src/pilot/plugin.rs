//! Framework plugin SPI (paper Listing 1).
//!
//! "The streaming frameworks specifics are encapsulated in a plugin.  A
//! framework plugin comprises of a PluginManager implementation of a
//! simple service provider interface (SPI) and a bootstrap script
//! executed on the resource."  The interface below mirrors the paper's
//! six functions: `submit_job`, `wait`, `extend`, `get_context`,
//! `get_config_data` (construction takes the description, as in the
//! paper's `__init__`).

use std::collections::BTreeMap;

use crate::broker::BrokerCluster;
use crate::cluster::{Machine, NodeId};
use crate::config::BootstrapModel;
use crate::engine::{MicroBatchEngine, TaskEngine};
use crate::error::Result;

use super::description::PilotComputeDescription;

/// Everything a plugin needs to bootstrap on the allocated resource.
pub struct PluginEnv {
    pub machine: Machine,
    /// Nodes granted to this pilot.
    pub nodes: Vec<NodeId>,
    pub description: PilotComputeDescription,
}

/// The native framework handle a plugin exposes once running — the
/// paper's *context object* ("the native client application, i.e., the
/// Spark Context, Dask Client or Kafka Client object", Listing 6).
#[derive(Clone, Debug)]
pub enum FrameworkContext {
    /// Kafka: the broker cluster client.
    Kafka(BrokerCluster),
    /// Spark(-like): micro-batch engine handle.
    MicroBatch(MicroBatchEngine),
    /// Dask(-like) and Flink(-like): task engine handle.
    TaskPar(TaskEngine),
}

impl FrameworkContext {
    pub fn as_kafka(&self) -> Option<&BrokerCluster> {
        match self {
            FrameworkContext::Kafka(c) => Some(c),
            _ => None,
        }
    }

    pub fn as_microbatch(&self) -> Option<&MicroBatchEngine> {
        match self {
            FrameworkContext::MicroBatch(e) => Some(e),
            _ => None,
        }
    }

    pub fn as_taskpar(&self) -> Option<&TaskEngine> {
        match self {
            FrameworkContext::TaskPar(e) => Some(e),
            _ => None,
        }
    }
}

/// The plugin SPI (paper Listing 1: `ManagerPlugin`).
pub trait ManagerPlugin: Send {
    /// Launch the framework on the pilot's nodes (the bootstrap script).
    fn submit_job(&mut self, env: &PluginEnv) -> Result<()>;

    /// Block until the framework is up; returns the modeled bootstrap
    /// duration in (virtual) seconds — recorded for Figure 6.
    fn wait(&mut self) -> Result<f64>;

    /// Add nodes to the running framework (pilot extension).
    fn extend(&mut self, env: &PluginEnv, new_nodes: &[NodeId]) -> Result<()>;

    /// The native framework context (paper Listing 6).
    fn get_context(&self) -> Result<FrameworkContext>;

    /// Framework configuration data (connection endpoints etc.).
    fn get_config_data(&self) -> BTreeMap<String, String>;

    /// The bootstrap cost model this plugin uses (exposed so the
    /// simulation plane and Figure 6 share one source of truth).
    fn bootstrap_model(&self) -> BootstrapModel;
}
