//! Pilot-Compute-Descriptions (paper Listing 2).
//!
//! A description is a simple key/value-style record naming the resource,
//! the node count, the framework type, and optionally a *parent pilot*
//! — referencing a parent marks this pilot as an extension that adds
//! its nodes to the parent's framework cluster (paper Listing 4).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Stream-framework kinds Pilot-Streaming can provision (paper §4.3:
/// "Currently, Pilot-Streaming supports Kafka, Spark, Dask, and Flink").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FrameworkKind {
    Kafka,
    Spark,
    Dask,
    Flink,
}

impl FrameworkKind {
    pub fn name(self) -> &'static str {
        match self {
            FrameworkKind::Kafka => "kafka",
            FrameworkKind::Spark => "spark",
            FrameworkKind::Dask => "dask",
            FrameworkKind::Flink => "flink",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "kafka" => Ok(FrameworkKind::Kafka),
            "spark" => Ok(FrameworkKind::Spark),
            "dask" => Ok(FrameworkKind::Dask),
            "flink" => Ok(FrameworkKind::Flink),
            other => Err(Error::Pilot(format!("unknown framework '{other}'"))),
        }
    }

    /// The framework-native configuration key naming per-node worker
    /// parallelism (Spark executors, Dask workers, Flink task slots) —
    /// the single source of truth shared by the framework plugins and
    /// the application layer's stage specs.  `None` for Kafka, whose
    /// parallelism is one broker per node.
    pub fn parallelism_key(self) -> Option<&'static str> {
        match self {
            FrameworkKind::Kafka => None,
            FrameworkKind::Spark => Some("executors_per_node"),
            FrameworkKind::Dask => Some("workers_per_node"),
            FrameworkKind::Flink => Some("taskmanager.numberOfTaskSlots"),
        }
    }
}

impl std::fmt::Display for FrameworkKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// The paper's `pilot_compute_description` dictionary, typed.
#[derive(Debug, Clone)]
pub struct PilotComputeDescription {
    /// Resource URL, e.g. `slurm://wrangler` or `local://localhost`.
    pub resource: String,
    pub working_directory: String,
    pub number_of_nodes: usize,
    pub cores_per_node: usize,
    pub framework: FrameworkKind,
    /// Extension pilots reference their parent (Listing 4:
    /// `pilot_compute_description['parent'] = parent_pilot_id`).
    pub parent_pilot: Option<String>,
    /// Walltime request, minutes.
    pub walltime_minutes: u64,
    /// Framework-native extra configuration (spark-env style knobs).
    pub config: BTreeMap<String, String>,
}

impl PilotComputeDescription {
    pub fn new(resource: &str, framework: FrameworkKind, nodes: usize) -> Self {
        PilotComputeDescription {
            resource: resource.to_string(),
            working_directory: "/tmp/pilot-streaming".into(),
            number_of_nodes: nodes,
            cores_per_node: 24,
            framework,
            parent_pilot: None,
            walltime_minutes: 59,
            config: BTreeMap::new(),
        }
    }

    /// Mark as an extension of `parent` (dynamic scaling, Listing 4).
    pub fn with_parent(mut self, parent: &str) -> Self {
        self.parent_pilot = Some(parent.to_string());
        self
    }

    pub fn with_config(mut self, key: &str, value: &str) -> Self {
        self.config.insert(key.to_string(), value.to_string());
        self
    }

    /// Per-node worker parallelism read from the framework's
    /// [`FrameworkKind::parallelism_key`] config entry, or `default`.
    pub fn parallelism_per_node(&self, default: usize) -> usize {
        self.framework
            .parallelism_key()
            .and_then(|key| self.config.get(key))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Scheme part of the resource URL ("slurm", "local", ...).
    pub fn scheme(&self) -> &str {
        self.resource.split("://").next().unwrap_or("local")
    }

    pub fn validate(&self) -> Result<()> {
        if self.number_of_nodes == 0 {
            return Err(Error::Pilot("number_of_nodes must be > 0".into()));
        }
        if self.resource.is_empty() {
            return Err(Error::Pilot("resource must not be empty".into()));
        }
        Ok(())
    }
}

macro_rules! framework_description {
    ($(#[$doc:meta])* $name:ident, $kind:expr) => {
        $(#[$doc])*
        #[derive(Debug, Clone)]
        pub struct $name(pub PilotComputeDescription);

        impl $name {
            /// Description for `nodes` nodes on the default resource.
            pub fn new(nodes: usize) -> Self {
                $name(PilotComputeDescription::new(
                    "slurm://wrangler",
                    $kind,
                    nodes,
                ))
            }

            pub fn on(resource: &str, nodes: usize) -> Self {
                $name(PilotComputeDescription::new(resource, $kind, nodes))
            }

            pub fn with_parent(mut self, parent: &str) -> Self {
                self.0 = self.0.with_parent(parent);
                self
            }

            pub fn with_config(mut self, key: &str, value: &str) -> Self {
                self.0 = self.0.with_config(key, value);
                self
            }
        }

        impl From<$name> for PilotComputeDescription {
            fn from(d: $name) -> Self {
                d.0
            }
        }
    };
}

framework_description!(
    /// Convenience description for a pilot-managed Kafka cluster.
    KafkaDescription,
    FrameworkKind::Kafka
);
framework_description!(
    /// Convenience description for a pilot-managed Spark(-like) cluster.
    SparkDescription,
    FrameworkKind::Spark
);
framework_description!(
    /// Convenience description for a pilot-managed Dask(-like) cluster.
    DaskDescription,
    FrameworkKind::Dask
);
framework_description!(
    /// Convenience description for a pilot-managed Flink cluster.
    FlinkDescription,
    FrameworkKind::Flink
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn framework_parse_roundtrip() {
        for k in [
            FrameworkKind::Kafka,
            FrameworkKind::Spark,
            FrameworkKind::Dask,
            FrameworkKind::Flink,
        ] {
            assert_eq!(FrameworkKind::parse(k.name()).unwrap(), k);
        }
        assert!(FrameworkKind::parse("storm").is_err());
    }

    #[test]
    fn description_builder() {
        let d = SparkDescription::new(4)
            .with_config("spark.executor.memory", "32g")
            .with_parent("pilot-1");
        let pcd: PilotComputeDescription = d.into();
        assert_eq!(pcd.framework, FrameworkKind::Spark);
        assert_eq!(pcd.number_of_nodes, 4);
        assert_eq!(pcd.parent_pilot.as_deref(), Some("pilot-1"));
        assert_eq!(
            pcd.config.get("spark.executor.memory").map(|s| s.as_str()),
            Some("32g")
        );
        assert_eq!(pcd.scheme(), "slurm");
        pcd.validate().unwrap();
    }

    #[test]
    fn parallelism_keys_are_pinned_and_read_back() {
        // The app layer and the framework plugins share these keys; a
        // rename must update both sides through this single source.
        assert_eq!(FrameworkKind::Spark.parallelism_key(), Some("executors_per_node"));
        assert_eq!(FrameworkKind::Dask.parallelism_key(), Some("workers_per_node"));
        assert_eq!(
            FrameworkKind::Flink.parallelism_key(),
            Some("taskmanager.numberOfTaskSlots")
        );
        assert_eq!(FrameworkKind::Kafka.parallelism_key(), None);

        let pcd = PilotComputeDescription::new("local://x", FrameworkKind::Spark, 1)
            .with_config("executors_per_node", "3");
        assert_eq!(pcd.parallelism_per_node(2), 3);
        let pcd = PilotComputeDescription::new("local://x", FrameworkKind::Dask, 1);
        assert_eq!(pcd.parallelism_per_node(8), 8, "default when unset");
        let pcd = PilotComputeDescription::new("local://x", FrameworkKind::Kafka, 1)
            .with_config("executors_per_node", "3");
        assert_eq!(pcd.parallelism_per_node(1), 1, "kafka has no parallelism key");
    }

    #[test]
    fn validate_rejects_empty() {
        let mut pcd = PilotComputeDescription::new("local://x", FrameworkKind::Dask, 0);
        assert!(pcd.validate().is_err());
        pcd.number_of_nodes = 1;
        pcd.resource.clear();
        assert!(pcd.validate().is_err());
    }
}
