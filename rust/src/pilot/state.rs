//! Pilot lifecycle state machine.

use crate::error::{Error, Result};

/// States of a Pilot (superset of SAGA job states: a pilot also
/// bootstraps a framework inside its allocation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PilotState {
    /// Created, not yet submitted.
    New,
    /// Placeholder job waiting in the batch queue.
    Queued,
    /// Allocation granted; framework bootstrap in progress.
    Bootstrapping,
    /// Framework up; compute units / clients may connect.
    Running,
    /// Shutting down (releasing nodes).
    ShuttingDown,
    /// Terminated normally.
    Done,
    /// Terminated with an error.
    Failed,
}

impl PilotState {
    /// Legal transitions (used to guard coordinator bugs).
    pub fn can_transition_to(self, next: PilotState) -> bool {
        use PilotState::*;
        matches!(
            (self, next),
            (New, Queued)
                | (Queued, Bootstrapping)
                | (Bootstrapping, Running)
                | (Running, ShuttingDown)
                | (ShuttingDown, Done)
                | (New, Failed)
                | (Queued, Failed)
                | (Bootstrapping, Failed)
                | (Running, Failed)
        )
    }

    /// Apply a transition, erroring on illegal moves.
    pub fn transition(self, next: PilotState) -> Result<PilotState> {
        if self.can_transition_to(next) {
            Ok(next)
        } else {
            Err(Error::Pilot(format!(
                "illegal pilot transition {self:?} -> {next:?}"
            )))
        }
    }

    /// Terminal states.
    pub fn is_terminal(self) -> bool {
        matches!(self, PilotState::Done | PilotState::Failed)
    }

    /// Can the pilot accept work / be extended?
    pub fn is_active(self) -> bool {
        matches!(self, PilotState::Running)
    }
}

impl std::fmt::Display for PilotState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            PilotState::New => "NEW",
            PilotState::Queued => "QUEUED",
            PilotState::Bootstrapping => "BOOTSTRAPPING",
            PilotState::Running => "RUNNING",
            PilotState::ShuttingDown => "SHUTTING_DOWN",
            PilotState::Done => "DONE",
            PilotState::Failed => "FAILED",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use PilotState::*;

    #[test]
    fn happy_path_transitions() {
        let mut s = New;
        for next in [Queued, Bootstrapping, Running, ShuttingDown, Done] {
            s = s.transition(next).unwrap();
        }
        assert!(s.is_terminal());
    }

    #[test]
    fn illegal_transitions_rejected() {
        assert!(New.transition(Running).is_err());
        assert!(Done.transition(Running).is_err());
        assert!(Running.transition(Queued).is_err());
        assert!(Failed.transition(Queued).is_err());
    }

    #[test]
    fn failure_reachable_from_non_terminal() {
        for s in [New, Queued, Bootstrapping, Running] {
            assert!(s.can_transition_to(Failed), "{s:?}");
        }
        assert!(!ShuttingDown.can_transition_to(Failed));
    }

    #[test]
    fn activity_flags() {
        assert!(Running.is_active());
        assert!(!Queued.is_active());
        assert!(Done.is_terminal() && Failed.is_terminal());
    }
}
