//! The Pilot-Compute service: pilot lifecycle, extension, shrinking.
//!
//! This is the coordinator's control plane (paper Figure 4): the
//! application asks the service for a pilot with a
//! [`PilotComputeDescription`]; the service submits a placeholder job
//! through the SAGA adaptor, waits out the queue, allocates whole nodes
//! on the machine, bootstraps the framework plugin (the PS-Agent role)
//! and hands back a [`Pilot`] whose context object exposes the native
//! framework client.
//!
//! Dynamic scaling (paper Listing 4): creating a description that
//! references a *parent pilot* produces an extension pilot — its nodes
//! are added to the parent's framework at runtime; stopping the
//! extension shrinks the framework back and releases the nodes.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cluster::{Machine, NodeId};
use crate::error::{Error, Result};
use crate::plugins::create_plugin;
use crate::saga::{JobDescription, LocalAdaptor, ResourceAdaptor, SimSlurmAdaptor};

use super::description::{
    DaskDescription, KafkaDescription, PilotComputeDescription, SparkDescription,
};
use super::plugin::{FrameworkContext, ManagerPlugin, PluginEnv};
use super::state::PilotState;

/// Startup time decomposition (the two bars of Figure 6: batch-job
/// placement vs framework initialization).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StartupBreakdown {
    pub queue_wait_secs: f64,
    pub bootstrap_secs: f64,
}

impl StartupBreakdown {
    pub fn total_secs(&self) -> f64 {
        self.queue_wait_secs + self.bootstrap_secs
    }
}

/// A live pilot.
pub struct Pilot {
    id: String,
    description: PilotComputeDescription,
    machine: Machine,
    state: Mutex<PilotState>,
    nodes: Mutex<Vec<NodeId>>,
    /// The framework plugin (None for extension pilots: they delegate
    /// to the parent's plugin).
    plugin: Mutex<Option<Box<dyn ManagerPlugin>>>,
    parent: Option<Arc<Pilot>>,
    startup: Mutex<Option<StartupBreakdown>>,
}

impl std::fmt::Debug for Pilot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pilot")
            .field("id", &self.id)
            .field("state", &self.state())
            .field("nodes", &self.nodes().len())
            .field("framework", &self.description.framework.name())
            .finish()
    }
}

impl Pilot {
    pub fn id(&self) -> &str {
        &self.id
    }

    pub fn description(&self) -> &PilotComputeDescription {
        &self.description
    }

    /// The framework this pilot manages (extensions report the parent's
    /// framework, which is the same by construction).
    pub fn framework(&self) -> crate::pilot::FrameworkKind {
        self.description.framework
    }

    pub fn state(&self) -> PilotState {
        *self.state.lock().unwrap()
    }

    fn set_state(&self, next: PilotState) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        *st = st.transition(next)?;
        Ok(())
    }

    pub fn nodes(&self) -> Vec<NodeId> {
        self.nodes.lock().unwrap().clone()
    }

    /// Startup breakdown (Fig 6), available once Running.
    pub fn startup(&self) -> Option<StartupBreakdown> {
        *self.startup.lock().unwrap()
    }

    /// The native framework context (paper Listing 6).  Extension
    /// pilots return their parent's context.
    pub fn context(&self) -> Result<FrameworkContext> {
        if let Some(parent) = &self.parent {
            return parent.context();
        }
        let plugin = self.plugin.lock().unwrap();
        plugin
            .as_ref()
            .ok_or_else(|| Error::Pilot(format!("pilot {}: no plugin", self.id)))?
            .get_context()
    }

    /// Framework configuration (endpoints etc.).
    pub fn config_data(&self) -> BTreeMap<String, String> {
        if let Some(parent) = &self.parent {
            return parent.config_data();
        }
        self.plugin
            .lock()
            .unwrap()
            .as_ref()
            .map(|p| p.get_config_data())
            .unwrap_or_default()
    }
}

/// Kinds of pilot scaling-lifecycle events (see [`PilotScalingEvent`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PilotEventKind {
    /// A fresh (non-extension) pilot reached Running.
    Created,
    /// An extension pilot added nodes to its parent's framework.
    Extended,
    /// Nodes left a framework (extension stopped or in-place shrink).
    Shrunk,
    /// A base pilot stopped and released all its nodes.
    Stopped,
}

/// A resource-footprint change emitted by the service.  External
/// observers (experiment probes, loggers, dashboards) subscribe via
/// [`PilotComputeService::add_scaling_hook`] to see every extend/shrink
/// without polling; the autoscaler itself keeps its own
/// [`crate::metrics::ScalingTimeline`] and does not depend on hooks.
#[derive(Debug, Clone)]
pub struct PilotScalingEvent {
    pub pilot_id: String,
    /// The parent pilot for extension events.
    pub parent_id: Option<String>,
    pub kind: PilotEventKind,
    /// Nodes involved in this event.
    pub nodes: usize,
}

/// Callback invoked on every scaling-lifecycle event.
pub type ScalingHook = Arc<dyn Fn(&PilotScalingEvent) + Send + Sync>;

/// The service (paper §4.2's `PilotComputeService`).
pub struct PilotComputeService {
    machine: Machine,
    adaptor: Arc<dyn ResourceAdaptor>,
    /// Maps modeled queue/bootstrap seconds to real sleeping.
    time_scale: f64,
    pilots: Mutex<HashMap<String, Arc<Pilot>>>,
    next_id: AtomicU64,
    hooks: Mutex<Vec<ScalingHook>>,
}

impl PilotComputeService {
    /// Service over `machine` with a modeled SLURM queue and no real
    /// sleeping (tests, benches).
    pub fn new(machine: Machine) -> Self {
        Self::with_adaptor(machine, SimSlurmAdaptor::wrangler(0.0), 0.0)
    }

    /// Service with immediate (interactive) placement.
    pub fn local(machine: Machine) -> Self {
        Self::with_adaptor(machine, Arc::new(LocalAdaptor::new()), 0.0)
    }

    pub fn with_adaptor(
        machine: Machine,
        adaptor: Arc<dyn ResourceAdaptor>,
        time_scale: f64,
    ) -> Self {
        PilotComputeService {
            machine,
            adaptor,
            time_scale,
            pilots: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            hooks: Mutex::new(Vec::new()),
        }
    }

    /// Register a hook observing every scaling-lifecycle event
    /// (create/extend/shrink/stop).  Hooks run synchronously on the
    /// thread performing the lifecycle change; keep them cheap.
    pub fn add_scaling_hook(&self, hook: ScalingHook) {
        self.hooks.lock().unwrap().push(hook);
    }

    fn fire(&self, event: PilotScalingEvent) {
        // Snapshot the hooks first: a hook may call back into the
        // service (even add_scaling_hook) without deadlocking.
        let hooks: Vec<ScalingHook> = self.hooks.lock().unwrap().clone();
        for hook in hooks {
            hook(&event);
        }
    }

    pub fn machine(&self) -> &Machine {
        &self.machine
    }

    pub fn pilot(&self, id: &str) -> Option<Arc<Pilot>> {
        self.pilots.lock().unwrap().get(id).cloned()
    }

    pub fn pilots(&self) -> Vec<Arc<Pilot>> {
        self.pilots.lock().unwrap().values().cloned().collect()
    }

    /// Create (and fully start) a pilot from a description.
    ///
    /// Returns once the framework is Running.  Descriptions with a
    /// `parent_pilot` become extension pilots (paper Listing 4).
    pub fn create_pilot(
        &self,
        description: impl Into<PilotComputeDescription>,
    ) -> Result<Arc<Pilot>> {
        let description = description.into();
        description.validate()?;
        let id = format!(
            "pilot-{}-{}",
            description.framework.name(),
            self.next_id.fetch_add(1, Ordering::Relaxed)
        );

        let parent = match &description.parent_pilot {
            Some(pid) => Some(
                self.pilot(pid)
                    .ok_or_else(|| Error::Pilot(format!("unknown parent pilot {pid}")))?,
            ),
            None => None,
        };
        if let Some(p) = &parent {
            if !p.state().is_active() {
                return Err(Error::Pilot(format!(
                    "parent pilot {} is not running",
                    p.id()
                )));
            }
            if p.description.framework != description.framework {
                return Err(Error::Pilot(format!(
                    "extension framework {} != parent framework {}",
                    description.framework, p.description.framework
                )));
            }
        }

        let pilot = Arc::new(Pilot {
            id: id.clone(),
            description: description.clone(),
            machine: self.machine.clone(),
            state: Mutex::new(PilotState::New),
            nodes: Mutex::new(Vec::new()),
            plugin: Mutex::new(None),
            parent,
            startup: Mutex::new(None),
        });

        // NEW -> QUEUED: submit the placeholder job.
        let job = self.adaptor.submit(JobDescription {
            executable: description.framework.name().into(),
            number_of_nodes: description.number_of_nodes,
            cores_per_node: description.cores_per_node,
            walltime_secs: description.walltime_minutes * 60,
            ..Default::default()
        })?;
        pilot.set_state(PilotState::Queued)?;

        // Queue wait, then node allocation.
        let run = (|| -> Result<StartupBreakdown> {
            self.adaptor.wait_running(job)?;
            let queue_wait_secs = self.adaptor.info(job)?.queue_wait_secs;
            if self.time_scale > 0.0 && self.adaptor.scheme() == "fork" {
                // LocalAdaptor doesn't sleep; SimSlurm already did.
            }
            let nodes = self
                .machine
                .allocate(&pilot.id, description.number_of_nodes)?;
            *pilot.nodes.lock().unwrap() = nodes.clone();
            pilot.set_state(PilotState::Bootstrapping)?;

            let env = PluginEnv {
                machine: self.machine.clone(),
                nodes,
                description: description.clone(),
            };
            let bootstrap_secs = match &pilot.parent {
                // Extension: add our nodes to the parent's framework.
                Some(parent) => {
                    let mut plugin = parent.plugin.lock().unwrap();
                    let plugin = plugin.as_mut().ok_or_else(|| {
                        Error::Pilot(format!("parent {} has no plugin", parent.id()))
                    })?;
                    let t0 = std::time::Instant::now();
                    plugin.extend(&env, &env.nodes)?;
                    // Floor at the modeled per-framework extension cost
                    // (shared with the autoscale planner, so plan
                    // estimates and recorded bootstraps agree).
                    t0.elapsed().as_secs_f64().max(crate::plugins::extension_cost_secs(
                        description.framework,
                        env.nodes.len(),
                    ))
                }
                // Fresh framework bootstrap.
                None => {
                    let mut plugin = create_plugin(&description, self.time_scale)?;
                    plugin.submit_job(&env)?;
                    let secs = plugin.wait()?;
                    *pilot.plugin.lock().unwrap() = Some(plugin);
                    secs
                }
            };
            Ok(StartupBreakdown {
                queue_wait_secs,
                bootstrap_secs,
            })
        })();

        match run {
            Ok(breakdown) => {
                *pilot.startup.lock().unwrap() = Some(breakdown);
                pilot.set_state(PilotState::Running)?;
                self.pilots.lock().unwrap().insert(id, pilot.clone());
                self.fire(PilotScalingEvent {
                    pilot_id: pilot.id.clone(),
                    parent_id: pilot.parent.as_ref().map(|p| p.id.clone()),
                    kind: if pilot.parent.is_some() {
                        PilotEventKind::Extended
                    } else {
                        PilotEventKind::Created
                    },
                    nodes: pilot.nodes().len(),
                });
                Ok(pilot)
            }
            Err(e) => {
                let _ = pilot.set_state(PilotState::Failed);
                self.machine.release(&pilot.id);
                Err(e)
            }
        }
    }

    /// Extend `parent` by `nodes` nodes: sugar for an extension
    /// description (paper Listing 4).
    pub fn extend_pilot(&self, parent: &Arc<Pilot>, nodes: usize) -> Result<Arc<Pilot>> {
        let mut pcd = PilotComputeDescription::new(
            &parent.description.resource,
            parent.description.framework,
            nodes,
        );
        pcd.parent_pilot = Some(parent.id().to_string());
        pcd.cores_per_node = parent.description.cores_per_node;
        self.create_pilot(pcd)
    }

    /// Stop a pilot and release its nodes.
    ///
    /// Stopping an extension pilot shrinks the parent's framework
    /// ("if the resources are not needed anymore, the pilot can be
    /// stopped and the cluster will automatically resize", §4.2).
    pub fn stop_pilot(&self, pilot: &Arc<Pilot>) -> Result<()> {
        pilot.set_state(PilotState::ShuttingDown)?;
        let nodes = pilot.nodes();
        match &pilot.parent {
            Some(parent) => {
                // Shrink the parent's framework off our nodes.
                if let Ok(ctx) = parent.context() {
                    match ctx {
                        FrameworkContext::Kafka(c) => {
                            let _ = c.remove_brokers(&nodes);
                        }
                        FrameworkContext::MicroBatch(e) => e.remove_executors(&nodes),
                        FrameworkContext::TaskPar(e) => e.remove_workers(&nodes),
                    }
                }
            }
            None => {
                if let Ok(ctx) = pilot.context() {
                    match ctx {
                        FrameworkContext::Kafka(c) => c.stop(),
                        FrameworkContext::MicroBatch(e) => e.stop(),
                        FrameworkContext::TaskPar(e) => e.stop(),
                    }
                }
            }
        }
        pilot.machine.release(&pilot.id);
        pilot.set_state(PilotState::Done)?;
        self.pilots.lock().unwrap().remove(pilot.id());
        self.fire(PilotScalingEvent {
            pilot_id: pilot.id.clone(),
            parent_id: pilot.parent.as_ref().map(|p| p.id.clone()),
            kind: if pilot.parent.is_some() {
                PilotEventKind::Shrunk
            } else {
                PilotEventKind::Stopped
            },
            nodes: nodes.len(),
        });
        Ok(())
    }

    /// Shrink a base pilot *in place* by `nodes` nodes (the complement
    /// of [`extend_pilot`](Self::extend_pilot) when the resources were
    /// part of the original allocation rather than an extension pilot):
    /// the framework drains off the released nodes, which go back to the
    /// machine.  At least one node always remains; extension pilots are
    /// shrunk by stopping them instead.  Returns the released node ids.
    pub fn shrink_pilot(&self, pilot: &Arc<Pilot>, nodes: usize) -> Result<Vec<NodeId>> {
        if pilot.parent.is_some() {
            return Err(Error::Pilot(format!(
                "pilot {}: stop the extension pilot to shrink its parent",
                pilot.id
            )));
        }
        if !pilot.state().is_active() {
            return Err(Error::Pilot(format!(
                "pilot {}: cannot shrink in state {}",
                pilot.id,
                pilot.state()
            )));
        }
        if nodes == 0 {
            return Ok(Vec::new());
        }
        // Detach the tail atomically, so concurrent shrinks can never
        // claim the same nodes or drop below the one-node floor.
        let released: Vec<NodeId> = {
            let mut held = pilot.nodes.lock().unwrap();
            if nodes >= held.len() {
                return Err(Error::Pilot(format!(
                    "pilot {}: cannot shrink {nodes} of {} nodes (one must remain)",
                    pilot.id,
                    held.len()
                )));
            }
            let keep = held.len() - nodes;
            held.split_off(keep)
        };
        // Drain the framework off the released nodes; a broker that
        // refuses (e.g. would lose its last broker) aborts the shrink
        // with the allocation restored.
        if let Ok(ctx) = pilot.context() {
            match ctx {
                FrameworkContext::Kafka(c) => {
                    if let Err(e) = c.remove_brokers(&released) {
                        pilot.nodes.lock().unwrap().extend(released);
                        return Err(e);
                    }
                }
                FrameworkContext::MicroBatch(e) => e.remove_executors(&released),
                FrameworkContext::TaskPar(e) => e.remove_workers(&released),
            }
        }
        pilot.machine.release_nodes(&pilot.id, &released);
        self.fire(PilotScalingEvent {
            pilot_id: pilot.id.clone(),
            parent_id: None,
            kind: PilotEventKind::Shrunk,
            nodes: released.len(),
        });
        Ok(released)
    }

    // ------------------------------------------------------------------
    // Convenience starters (used by examples and the Mini-Apps)
    // ------------------------------------------------------------------

    /// Start a pilot-managed Kafka cluster; returns the broker client.
    pub fn start_kafka(
        &self,
        d: KafkaDescription,
    ) -> Result<(Arc<Pilot>, crate::broker::BrokerCluster)> {
        let pilot = self.create_pilot(d)?;
        let ctx = pilot.context()?;
        let cluster = ctx
            .as_kafka()
            .ok_or_else(|| Error::Pilot("kafka pilot has non-kafka context".into()))?
            .clone();
        Ok((pilot, cluster))
    }

    /// Start a pilot-managed Spark(-like) micro-batch engine.
    pub fn start_spark(
        &self,
        d: SparkDescription,
    ) -> Result<(Arc<Pilot>, crate::engine::MicroBatchEngine)> {
        let pilot = self.create_pilot(d)?;
        let ctx = pilot.context()?;
        let engine = ctx
            .as_microbatch()
            .ok_or_else(|| Error::Pilot("spark pilot has non-spark context".into()))?
            .clone();
        Ok((pilot, engine))
    }

    /// Start a pilot-managed Dask(-like) task engine.
    pub fn start_dask(
        &self,
        d: DaskDescription,
    ) -> Result<(Arc<Pilot>, crate::engine::TaskEngine)> {
        let pilot = self.create_pilot(d)?;
        let ctx = pilot.context()?;
        let engine = ctx
            .as_taskpar()
            .ok_or_else(|| Error::Pilot("dask pilot has non-dask context".into()))?
            .clone();
        Ok((pilot, engine))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn service(nodes: usize) -> PilotComputeService {
        PilotComputeService::new(Machine::unthrottled(nodes))
    }

    #[test]
    fn kafka_pilot_full_lifecycle() {
        let svc = service(4);
        let (pilot, cluster) = svc.start_kafka(KafkaDescription::new(2)).unwrap();
        assert_eq!(pilot.state(), PilotState::Running);
        assert_eq!(pilot.nodes().len(), 2);
        assert_eq!(svc.machine().free_nodes(), 2);
        let s = pilot.startup().unwrap();
        assert!(s.queue_wait_secs > 0.0, "slurm queue wait recorded");
        assert!(s.bootstrap_secs > 0.0);
        cluster.create_topic("t", 4).unwrap();
        svc.stop_pilot(&pilot).unwrap();
        assert_eq!(pilot.state(), PilotState::Done);
        assert_eq!(svc.machine().free_nodes(), 4);
        assert!(cluster.is_stopped());
    }

    #[test]
    fn pilot_fails_when_machine_full() {
        let svc = service(2);
        let err = svc.create_pilot(KafkaDescription::new(3)).unwrap_err();
        assert!(matches!(err, Error::Pilot(_)), "{err}");
        assert_eq!(svc.machine().free_nodes(), 2, "nothing leaked");
    }

    #[test]
    fn extension_pilot_grows_and_shrinks_kafka() {
        let svc = service(6);
        let (parent, cluster) = svc.start_kafka(KafkaDescription::new(2)).unwrap();
        cluster.create_topic("t", 6).unwrap();
        let ext = svc.extend_pilot(&parent, 2).unwrap();
        assert_eq!(cluster.broker_nodes().len(), 4, "brokers extended");
        assert_eq!(svc.machine().free_nodes(), 2);
        // Shrink back.
        svc.stop_pilot(&ext).unwrap();
        assert_eq!(cluster.broker_nodes().len(), 2, "brokers shrunk");
        assert_eq!(svc.machine().free_nodes(), 4);
        svc.stop_pilot(&parent).unwrap();
    }

    #[test]
    fn extension_requires_matching_framework_and_running_parent() {
        let svc = service(6);
        let (kafka, _) = svc.start_kafka(KafkaDescription::new(1)).unwrap();
        let bad = PilotComputeDescription::new(
            "slurm://wrangler",
            crate::pilot::FrameworkKind::Spark,
            1,
        )
        .with_parent(kafka.id());
        assert!(svc.create_pilot(bad).is_err());
        svc.stop_pilot(&kafka).unwrap();
        let orphan = PilotComputeDescription::new(
            "slurm://wrangler",
            crate::pilot::FrameworkKind::Kafka,
            1,
        )
        .with_parent(kafka.id());
        assert!(svc.create_pilot(orphan).is_err(), "parent gone");
    }

    #[test]
    fn spark_extension_adds_executors() {
        let svc = service(4);
        let (parent, engine) = svc
            .start_spark(SparkDescription::new(1).with_config("executors_per_node", "2"))
            .unwrap();
        assert_eq!(engine.executor_count(), 2);
        let ext = svc.extend_pilot(&parent, 2).unwrap();
        assert_eq!(engine.executor_count(), 6);
        svc.stop_pilot(&ext).unwrap();
        // Draining is asynchronous; wait briefly.
        let t0 = std::time::Instant::now();
        while engine.executor_count() != 2 && t0.elapsed().as_secs() < 5 {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert_eq!(engine.executor_count(), 2, "executors drained");
        svc.stop_pilot(&parent).unwrap();
    }

    #[test]
    fn dask_pilot_runs_compute_units() {
        let svc = service(2);
        let (pilot, engine) = svc
            .start_dask(DaskDescription::new(1).with_config("workers_per_node", "2"))
            .unwrap();
        // Paper Listing 5: def compute(x): return x*x; pilot.submit(compute, 2).
        let fut = engine.submit(|_| 2 * 2).unwrap();
        assert_eq!(fut.wait().unwrap(), 4);
        svc.stop_pilot(&pilot).unwrap();
    }

    #[test]
    fn shrink_pilot_releases_nodes_in_place() {
        let svc = service(4);
        let (pilot, engine) = svc
            .start_spark(SparkDescription::new(3).with_config("executors_per_node", "1"))
            .unwrap();
        assert_eq!(engine.executor_count(), 3);
        assert_eq!(svc.machine().free_nodes(), 1);
        let released = svc.shrink_pilot(&pilot, 2).unwrap();
        assert_eq!(released.len(), 2);
        assert_eq!(pilot.nodes().len(), 1);
        assert_eq!(svc.machine().free_nodes(), 3);
        // Draining is asynchronous; wait for the executors to exit.
        let t0 = std::time::Instant::now();
        while engine.executor_count() != 1 && t0.elapsed().as_secs() < 5 {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert_eq!(engine.executor_count(), 1, "executors drained");
        // The last node cannot be shrunk away.
        assert!(svc.shrink_pilot(&pilot, 1).is_err());
        svc.stop_pilot(&pilot).unwrap();
        assert_eq!(svc.machine().free_nodes(), 4);
    }

    #[test]
    fn shrink_rejects_extensions_and_zero_is_noop() {
        let svc = service(4);
        let (parent, _) = svc.start_kafka(KafkaDescription::new(2)).unwrap();
        assert!(svc.shrink_pilot(&parent, 0).unwrap().is_empty());
        let ext = svc.extend_pilot(&parent, 1).unwrap();
        assert!(svc.shrink_pilot(&ext, 1).is_err(), "extensions stop, not shrink");
        svc.stop_pilot(&ext).unwrap();
        svc.stop_pilot(&parent).unwrap();
    }

    #[test]
    fn scaling_hooks_observe_lifecycle() {
        use super::PilotEventKind;
        use std::sync::Mutex as StdMutex;
        let svc = service(6);
        let seen: Arc<StdMutex<Vec<(PilotEventKind, usize)>>> = Arc::new(StdMutex::new(Vec::new()));
        let sink = seen.clone();
        svc.add_scaling_hook(Arc::new(move |e: &PilotScalingEvent| {
            sink.lock().unwrap().push((e.kind, e.nodes));
        }));
        let (pilot, _) = svc
            .start_spark(SparkDescription::new(2).with_config("executors_per_node", "1"))
            .unwrap();
        let ext = svc.extend_pilot(&pilot, 2).unwrap();
        svc.stop_pilot(&ext).unwrap();
        svc.shrink_pilot(&pilot, 1).unwrap();
        svc.stop_pilot(&pilot).unwrap();
        let events = seen.lock().unwrap().clone();
        assert_eq!(
            events,
            vec![
                (PilotEventKind::Created, 2),
                (PilotEventKind::Extended, 2),
                (PilotEventKind::Shrunk, 2),
                (PilotEventKind::Shrunk, 1),
                (PilotEventKind::Stopped, 1),
            ]
        );
    }

    #[test]
    fn startup_breakdown_scales_with_nodes() {
        let svc = service(8);
        let (p1, _) = svc.start_kafka(KafkaDescription::new(1)).unwrap();
        let (p4, _) = svc.start_kafka(KafkaDescription::new(4)).unwrap();
        let s1 = p1.startup().unwrap();
        let s4 = p4.startup().unwrap();
        assert!(s4.total_secs() > s1.total_secs());
        svc.stop_pilot(&p1).unwrap();
        svc.stop_pilot(&p4).unwrap();
    }
}
