//! Experiment harness: regenerates every table and figure of the
//! paper's evaluation (section 6).  Shared by the CLI
//! (`pilot-streaming exp <id>`) and the bench targets.
//!
//! | id       | paper     | harness                                    |
//! |----------|-----------|--------------------------------------------|
//! | fig6     | Figure 6  | startup grid (queue + bootstrap models)    |
//! | fig7     | Figure 7  | latency distributions @100 msg/s           |
//! | fig8     | Figure 8  | MASS producer throughput sweep             |
//! | fig9     | Figure 9  | MASA processing throughput sweep           |
//! | table1   | Table 1   | live Mini-App characterization             |
//! | headline | §6.5      | 32-node max-scale run                      |
//! | elastic  | §1, §4.2  | closed-loop autoscaling burst @ 32 nodes   |
//! | dag      | §4.1      | chained + branched dataflow, per-hop stats |

use crate::autoscale::{PartitionElastic, Planner, PlannerConfig, ThresholdPolicy};
use crate::broker::cloud::CloudBroker;
use crate::config::{CostPreset, ExperimentConfig};
use crate::error::Result;
use crate::metrics::{Recorder, Row};
use crate::pilot::FrameworkKind;
use crate::runtime::ModelRuntime;
use crate::sim::{
    startup_grid, wrangler_queue, CostModel, ElasticScenario, ElasticSim, LatencySim,
    ProcessingScenario, ProcessingSim, ProducerScenario, ProducerSim, SimMachine,
};
use crate::util::RateSchedule;

/// Resolve the cost model: calibrate from the real plane when artifacts
/// are available, otherwise fall back to the preset constants.
pub fn resolve_costs(config: &ExperimentConfig, calibrate: bool) -> CostModel {
    match config.preset {
        CostPreset::PaperEra => CostModel::paper_era(),
        CostPreset::Calibrated => {
            if calibrate {
                if let Ok(rt) = ModelRuntime::load_default() {
                    if let Ok(m) = CostModel::calibrate(&rt, 5) {
                        return m;
                    }
                }
            }
            CostModel::calibrated_default()
        }
    }
}

/// Figure 6: Kafka/Spark/Dask startup vs cluster size.
pub fn fig6(_config: &ExperimentConfig) -> Recorder {
    let rec = Recorder::new();
    let grid = startup_grid(
        &[
            FrameworkKind::Kafka,
            FrameworkKind::Spark,
            FrameworkKind::Dask,
            FrameworkKind::Flink,
        ],
        &[1, 2, 4, 8, 16, 32],
        wrangler_queue(),
    );
    for p in grid {
        rec.add(
            Row::new()
                .push("framework", p.framework.name())
                .push("nodes", p.nodes)
                .push("queue_wait_s", format!("{:.1}", p.queue_wait_secs))
                .push("framework_init_s", format!("{:.1}", p.framework_init_secs))
                .push("total_s", format!("{:.1}", p.total_secs())),
        );
    }
    rec
}

/// Figure 7: end-to-end latency at 100 msg/s across broker/processing
/// configurations.
pub fn fig7(config: &ExperimentConfig, costs: &CostModel) -> Recorder {
    let rec = Recorder::new();
    let sim = LatencySim::new(
        *costs,
        crate::config::messages::KMEANS_MSG_BYTES as f64,
        config.machine.nic_mbps * 1e6,
        config.seed,
    );
    let n = 20_000;
    let mut rows = vec![sim.kafka(n)];
    for window in [0.2, 1.0, 2.0, 4.0, 8.0] {
        rows.push(sim.spark_streaming(window, n));
    }
    rows.push(sim.cloud(&CloudBroker::kinesis(config.seed), n));
    rows.push(sim.cloud(&CloudBroker::pubsub(config.seed), n));
    for s in rows {
        rec.add(
            Row::new()
                .push("config", &s.config)
                .push("mean_s", format!("{:.3}", s.mean_secs))
                .push("p50_s", format!("{:.3}", s.p50_secs))
                .push("p99_s", format!("{:.3}", s.p99_secs)),
        );
    }
    rec
}

/// Figure 8: MASS producer throughput for KMeans-random, KMeans-static
/// and Lightsource across producer-node x broker-node configurations.
pub fn fig8(config: &ExperimentConfig, costs: &CostModel) -> Recorder {
    let rec = Recorder::new();
    let sim = ProducerSim::new(SimMachine::default(), *costs);
    for source in ["kmeans-random", "kmeans-static", "lightsource"] {
        let msg_bytes = if source == "lightsource" {
            crate::config::messages::LIGHTSOURCE_MSG_BYTES as f64
        } else {
            crate::config::messages::KMEANS_MSG_BYTES as f64
        };
        for brokers in [1usize, 2, 4] {
            for producers in [1usize, 2, 4, 8, 16] {
                let res = sim.run(&ProducerScenario {
                    source: source.into(),
                    msg_bytes,
                    producer_nodes: producers,
                    producers_per_node: config.producers_per_node,
                    broker_nodes: brokers,
                    partitions_per_node: config.partitions_per_node,
                    duration_secs: 120.0,
                });
                rec.add(
                    Row::new()
                        .push("source", source)
                        .push("producer_nodes", producers)
                        .push("broker_nodes", brokers)
                        .push("msgs_per_s", format!("{:.1}", res.msg_rate))
                        .push("mb_per_s", format!("{:.1}", res.mb_rate))
                        .push("broker_util", format!("{:.2}", res.broker_util)),
                );
            }
        }
    }
    rec
}

/// Input rates offered to the processing experiments: what 1 producer
/// node / 8 processes sustains (paper §6.4 uses exactly that source).
fn fig9_input_rate(source: &str, costs: &CostModel, config: &ExperimentConfig) -> f64 {
    let sim = ProducerSim::new(SimMachine::default(), *costs);
    let msg_bytes = if source == "lightsource" { 2e6 } else { 0.32e6 };
    sim.run(&ProducerScenario {
        source: source.into(),
        msg_bytes,
        producer_nodes: 1,
        producers_per_node: config.producers_per_node,
        broker_nodes: 4,
        partitions_per_node: config.partitions_per_node,
        duration_secs: 60.0,
    })
    .msg_rate
}

/// Figure 9: MASA processing throughput for KMeans, GridRec and ML-EM
/// across processing-node x broker-node configurations.
pub fn fig9(config: &ExperimentConfig, costs: &CostModel) -> Recorder {
    let rec = Recorder::new();
    let sim = ProcessingSim::new(SimMachine::default(), *costs);
    for processor in ["kmeans", "gridrec", "mlem"] {
        let source = if processor == "kmeans" {
            "kmeans-random"
        } else {
            "lightsource"
        };
        let input_rate = fig9_input_rate(source, costs, config);
        let msg_bytes = if processor == "kmeans" { 0.32e6 } else { 2e6 };
        for brokers in [1usize, 2, 4] {
            for nodes in [1usize, 2, 4, 8] {
                let res = sim.run(&ProcessingScenario {
                    processor: processor.into(),
                    msg_bytes,
                    input_rate,
                    processing_nodes: nodes,
                    broker_nodes: brokers,
                    partitions_per_node: config.partitions_per_node,
                    window_secs: config.window_secs,
                    windows: 10,
                });
                rec.add(
                    Row::new()
                        .push("processor", processor)
                        .push("processing_nodes", nodes)
                        .push("broker_nodes", brokers)
                        .push("input_msgs_per_s", format!("{:.1}", input_rate))
                        .push("msgs_per_s", format!("{:.1}", res.msg_rate))
                        .push("mb_per_s", format!("{:.1}", res.mb_rate))
                        .push("core_util", format!("{:.2}", res.core_util))
                        .push("behind", format!("{:.2}", res.behind_fraction)),
                );
            }
        }
    }
    rec
}

/// Elasticity: resource footprint vs input rate under a 10x burst at
/// 32-node Wrangler scale, driven through the virtual-time elastic
/// harness.  One row per micro-batch window: offered rate, usable
/// nodes, partitions, lag, and the decision taken — the timeline behind
/// the paper's "add/remove resources at runtime" claim, now closed-loop.
///
/// Under the paper-era preset the threshold policy replays the §6.4
/// regime through the pre-planner decision path.  Under the calibrated
/// preset (Rust-speed processors, which the paper-era rates never
/// saturate) the calibrated-scale scenario runs *through the planner*
/// instead, with the partition-elastic policy: the burst demands more
/// executor cores than the topic's 48 partitions can feed, so the
/// planner turns the mid-burst repartition intents into co-scheduled
/// plans — broker-extension steps land whenever the new partition
/// count would oversubscribe the 12-partition per-broker-node I/O
/// budget, and the `broker_nodes` column tracks the tier growing.
pub fn elasticity(config: &ExperimentConfig, costs: &CostModel) -> Recorder {
    let rec = Recorder::new();
    let machine = SimMachine {
        // Heavy reconstruction executors (memory-bound GridRec): keeps
        // executor cores below the 48-partition cap out to 24 nodes, so
        // the elastic regime spans the machine (§6.4's knee).
        executors_per_node: 2,
        ..Default::default()
    };
    let executors_per_node = machine.executors_per_node;
    let sim = ElasticSim::new(machine, *costs);
    let window = config.window_secs;
    let res = match config.preset {
        CostPreset::PaperEra => {
            let sc = ElasticScenario {
                processor: "gridrec".into(),
                schedule: RateSchedule::bursty(4.0, 40.0, 20.0 * window, 10.0 * window),
                window_secs: window,
                windows: 60,
                broker_nodes: 4,
                partitions_per_node: config.partitions_per_node,
                min_nodes: 2,
                max_nodes: 32,
                initial_nodes: 2,
                provision_delay_secs: 1.5 * window,
                repartition_delay_secs: window,
                max_partitions: 128,
                replication_factor: 1,
                node_death_window: None,
                ack_mode: crate::broker::AckMode::Leader,
                replica_lag_records: 0.0,
                racks: 0,
                rack_death_window: None,
            };
            let mut policy = ThresholdPolicy::new(600, 60)
                .with_sustain(1)
                .with_cooldown_secs(2.0 * window)
                .with_step(8);
            sim.run(&sc, &mut policy)
        }
        CostPreset::Calibrated => {
            let sc = ElasticScenario::calibrated_burst(window);
            let inner = ThresholdPolicy::new(20_000, 2_000)
                .with_sustain(1)
                .with_cooldown_secs(2.0 * window)
                .with_step(8);
            let mut policy = PartitionElastic::new(inner, executors_per_node);
            let planner = Planner::new(
                PlannerConfig::default()
                    .with_max_step(8)
                    .with_drain_horizon_secs(6.0 * window)
                    .with_partitions_per_broker_node(sc.partitions_per_node)
                    .with_max_broker_step(2),
            );
            sim.run_planned(&sc, &mut policy, &planner)
        }
    };
    elastic_rows(&res, &rec);
    rec
}

/// One CSV row per elastic-sim window (shared by `elastic` and its
/// `rackfail` preset; the fault columns are zero when no fault fires).
fn elastic_rows(res: &crate::sim::ElasticSimResult, rec: &Recorder) {
    for r in &res.rows {
        rec.add(
            Row::new()
                .push("t_s", format!("{:.0}", r.t_secs))
                .push("input_msgs_per_s", format!("{:.1}", r.input_rate))
                .push("nodes", r.nodes)
                .push("partitions", r.partitions)
                .push("broker_nodes", r.broker_nodes)
                .push("lag_msgs", format!("{:.0}", r.lag))
                .push("decision", r.decision)
                .push("behind", u8::from(r.behind))
                .push("lost_msgs", format!("{:.0}", r.lost))
                .push("truncated_records", format!("{:.0}", r.truncated))
                .push("reassignments", r.reassigned),
        );
    }
}

/// `exp elastic --preset rackfail`: the failure-domain lifecycle on the
/// elastic timeline.  A steady in-capacity rate keeps every scaling
/// intent at Hold, then a whole rack (2 of the 4 brokers) dies at
/// window 5: the `broker_nodes` column drops, `lost_msgs` records the
/// promoted followers' gap (Leader acks), the bounce's re-join two
/// windows later puts `truncated_records` on the timeline (the
/// divergent tails cut back to the survivors' fence), and the planner's
/// `ReassignReplicas` step — visible in the `reassignments` column —
/// re-spreads the crowded replica sets without buying a single broker.
pub fn elasticity_rackfail(config: &ExperimentConfig, costs: &CostModel) -> Recorder {
    let rec = Recorder::new();
    let machine = SimMachine {
        executors_per_node: 2,
        ..Default::default()
    };
    let sim = ElasticSim::new(machine, *costs);
    let sc = ElasticScenario::calibrated_rackfail(config.window_secs);
    let mut policy = ThresholdPolicy::new(20_000, 2_000)
        .with_sustain(1)
        .with_cooldown_secs(2.0 * config.window_secs)
        .with_step(8);
    let planner = Planner::new(
        PlannerConfig::default()
            .with_max_step(8)
            .with_drain_horizon_secs(6.0 * config.window_secs)
            .with_partitions_per_broker_node(sc.partitions_per_node)
            .with_max_broker_step(2),
    );
    let res = sim.run_planned(&sc, &mut policy, &planner);
    elastic_rows(&res, &rec);
    rec
}

/// Table 1: live characterization of both Mini-App workloads on the
/// real plane (single node, real broker + real XLA execution).
pub fn table1(runtime: &ModelRuntime) -> Result<Recorder> {
    use crate::cluster::Machine;
    use crate::engine::{MicroBatchEngine, TaskEngine};
    use crate::miniapp::{MasaApp, MasaConfig, MassConfig, MassSource, ProcessorKind, SourceKind};
    use std::sync::Arc;
    use std::time::Duration;

    let rec = Recorder::new();
    let km = runtime.manifest().kmeans.clone();
    for (name, kind, source, msgs) in [
        (
            "kmeans",
            ProcessorKind::KMeans,
            SourceKind::KmeansRandom { n_centroids: km.k },
            20usize,
        ),
        (
            "lightsource-gridrec",
            ProcessorKind::GridRec,
            SourceKind::Lightsource {
                template: Arc::new(runtime.read_f32_file("template_sinogram.bin")?),
            },
            10usize,
        ),
    ] {
        let machine = Machine::unthrottled(3);
        let cluster = crate::broker::BrokerCluster::new(machine.clone(), vec![0]);
        cluster.create_topic("t1", 4)?;
        let producer_engine = TaskEngine::new(machine.clone(), vec![1], 2);
        let engine = MicroBatchEngine::new(machine, vec![2], 2);
        let masa = MasaApp::new(
            MasaConfig::new(kind, "t1", Duration::from_millis(100)),
            runtime.clone(),
        );
        masa.processor.warmup()?;
        let job = masa.start(&engine, cluster.clone())?;

        let mut cfg = MassConfig::new(source, "t1");
        cfg.messages_per_producer = msgs / 2;
        let mass = MassSource::new(cfg);
        let report = mass.run(&producer_engine, &cluster, 2)?;

        // Wait for the consumer to drain.
        let deadline = std::time::Instant::now() + Duration::from_secs(120);
        while job.stats().processed.messages() < report.messages
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(50));
        }
        let stats = job.stop();
        engine.stop();
        producer_engine.stop();

        rec.add(
            Row::new()
                .push("application", name)
                .push("data_source", mass.config().source.name())
                .push("produced_msgs", report.messages)
                .push("produce_mb_s", format!("{:.1}", report.mb_rate()))
                .push("processed_msgs", stats.processed.messages())
                .push(
                    "proc_latency_p50_s",
                    format!("{:.3}", stats.record_latency.p50_secs()),
                )
                .push(
                    "exec_per_msg_ms",
                    format!(
                        "{:.1}",
                        masa.processor.stats.exec_secs.mean_secs() * 1e3
                    ),
                ),
        );
    }
    Ok(rec)
}

/// §6.5 headline: 32 nodes / 1536 vcores; lightsource producer
/// throughput up to ~390 MB/s; processing side is the bottleneck.
pub fn headline(config: &ExperimentConfig, costs: &CostModel) -> Recorder {
    let rec = Recorder::new();
    let psim = ProducerSim::new(SimMachine::default(), *costs);
    // Max-scale split of 32 nodes: 16 producers + 4 brokers + 8
    // processing + pilots overhead.
    let prod = psim.run(&ProducerScenario {
        source: "lightsource".into(),
        msg_bytes: 2e6,
        producer_nodes: 16,
        producers_per_node: config.producers_per_node,
        broker_nodes: 4,
        partitions_per_node: config.partitions_per_node,
        duration_secs: 120.0,
    });
    let csim = ProcessingSim::new(SimMachine::default(), *costs);
    let proc = csim.run(&ProcessingScenario {
        processor: "gridrec".into(),
        msg_bytes: 2e6,
        input_rate: prod.msg_rate,
        processing_nodes: 8,
        broker_nodes: 4,
        partitions_per_node: config.partitions_per_node,
        window_secs: config.window_secs,
        windows: 10,
    });
    rec.add(
        Row::new()
            .push("total_nodes", 32)
            .push("vcores", 32 * config.machine.cores_per_node * 2)
            .push("producer_mb_s", format!("{:.0}", prod.mb_rate))
            .push("producer_msgs_s", format!("{:.0}", prod.msg_rate))
            .push("processing_msgs_s", format!("{:.0}", proc.msg_rate))
            .push(
                "processed_fraction",
                format!("{:.2}", proc.msg_rate / prod.msg_rate.max(1e-9)),
            ),
    );
    rec
}

/// `dag`: a chained + branched dataflow on the real in-process plane —
/// source → reconstruct → split(hot/cold) → merge → archive — drained
/// topologically, reporting per-hop processed/emitted counts and lag.
pub fn dag(_config: &ExperimentConfig) -> Result<Recorder> {
    use crate::app::{
        CountingProcessor, MergeSpec, RelayProcessor, SourceSpec, SplitRoute, SplitSpec,
        StageSpec, StreamingApp,
    };
    use crate::cluster::Machine;
    use crate::miniapp::{MassConfig, SourceKind};
    use crate::pilot::{KafkaDescription, PilotComputeService};
    use std::sync::Arc;
    use std::time::Duration;

    let window = Duration::from_millis(30);
    let app = StreamingApp::builder()
        .broker(
            KafkaDescription::new(1),
            &[("raw", 2), ("frames", 2), ("hot", 2), ("cold", 2), ("merged", 2)],
        )
        .source(
            SourceSpec::mass(MassConfig::new(SourceKind::KmeansStatic, "raw"))
                .with_name("gen")
                .with_producers(2)
                .with_total_messages(48),
        )
        .stage(
            StageSpec::new("reconstruct", "raw", RelayProcessor::new(1))
                .with_window(window)
                .with_output_topic("frames"),
        )
        .split(
            SplitSpec::new("route", "frames", &["hot", "cold"], SplitRoute::KeyHash)
                .with_key_bytes(1)
                .with_window(window),
        )
        .merge(
            MergeSpec::new("fan-in", &["hot", "cold"], "merged")
                .with_key_bytes(1)
                .with_window(window),
        )
        .stage(StageSpec::new("archive", "merged", CountingProcessor::new()).with_window(window))
        .drain_timeout(Duration::from_secs(60))
        .build()?;
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(12)));
    let handle = app.launch(&service)?;
    handle.await_sources()?;
    let report = handle.drain_and_stop()?;
    let rec = Recorder::new();
    for s in &report.stages {
        rec.add(
            Row::new()
                .push("node", &s.name)
                .push("topic", &s.topic)
                .push("processed", s.processed_messages)
                .push("emitted", s.emitted_messages)
                .push("lag", s.lag)
                .push("drained", report.drained),
        );
    }
    Ok(rec)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(preset: CostPreset) -> ExperimentConfig {
        ExperimentConfig {
            preset,
            ..Default::default()
        }
    }

    #[test]
    fn fig6_produces_full_grid() {
        let rec = fig6(&cfg(CostPreset::PaperEra));
        let csv = rec.to_csv();
        assert_eq!(csv.lines().count(), 1 + 4 * 6, "4 frameworks x 6 sizes");
        assert!(csv.contains("kafka"));
        assert!(csv.contains("dask"));
    }

    #[test]
    fn dag_experiment_drains_and_reports_every_hop() {
        let rec = dag(&cfg(CostPreset::PaperEra)).expect("dag experiment");
        let csv = rec.to_csv();
        for node in ["reconstruct", "route", "fan-in:hot", "fan-in:cold", "archive"] {
            assert!(csv.contains(node), "missing hop {node}: {csv}");
        }
        // Every row carries drained=true (topological drain completed).
        for line in csv.lines().skip(1) {
            assert!(line.ends_with(",true"), "undrained row: {line}");
        }
    }

    #[test]
    fn fig7_has_all_configs() {
        let config = cfg(CostPreset::PaperEra);
        let costs = CostModel::paper_era();
        let csv = fig7(&config, &costs).to_csv();
        for c in ["kafka", "spark-0.2s", "spark-8s", "kinesis", "pubsub"] {
            assert!(csv.contains(c), "missing {c}: {csv}");
        }
    }

    #[test]
    fn fig8_and_fig9_shapes() {
        let config = cfg(CostPreset::PaperEra);
        let costs = CostModel::paper_era();
        let f8 = fig8(&config, &costs).to_csv();
        assert_eq!(f8.lines().count(), 1 + 3 * 3 * 5);
        let f9 = fig9(&config, &costs).to_csv();
        assert_eq!(f9.lines().count(), 1 + 3 * 3 * 4);
    }

    #[test]
    fn elasticity_traces_footprint_against_rate() {
        let config = cfg(CostPreset::PaperEra);
        let costs = CostModel::paper_era();
        let rec = elasticity(&config, &costs);
        let csv = rec.to_csv();
        assert_eq!(csv.lines().count(), 1 + 60, "one row per window");
        assert!(csv.starts_with(
            "t_s,input_msgs_per_s,nodes,partitions,broker_nodes,lag_msgs,decision,behind"
        ));
        // The burst must be visible both in the input and the footprint.
        let nodes: Vec<usize> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(2).unwrap().parse().unwrap())
            .collect();
        let peak = *nodes.iter().max().unwrap();
        assert!(peak > 2 && peak <= 32, "peak {peak}");
        assert_eq!(*nodes.last().unwrap(), 2, "footprint returns to the floor");
    }

    #[test]
    fn elasticity_calibrated_moves_the_knee() {
        let config = cfg(CostPreset::Calibrated);
        let costs = CostModel::calibrated_default();
        let csv = elasticity(&config, &costs).to_csv();
        // Partition column present and the count grows past the
        // initial 48 mid-run: the §6.4 cap moved with the fleet.
        let partitions: Vec<usize> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(3).unwrap().parse().unwrap())
            .collect();
        assert_eq!(partitions[0], 48);
        assert!(
            partitions.iter().any(|p| *p > 48),
            "partition count never grew: {partitions:?}"
        );
        // And the fleet tracks the burst past the 24-node knee.
        let nodes: Vec<usize> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(2).unwrap().parse().unwrap())
            .collect();
        assert!(*nodes.iter().max().unwrap() > 24);
        assert_eq!(*nodes.last().unwrap(), 2, "footprint returns to the floor");
        // The co-scheduled plan is visible on the timeline: when the
        // grown partition count oversubscribes the 12-partition
        // per-broker-node I/O budget, broker-extension steps land and
        // the broker_nodes column moves with them.
        let brokers: Vec<usize> = csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(4).unwrap().parse().unwrap())
            .collect();
        assert_eq!(brokers[0], 4);
        assert!(
            brokers.iter().any(|b| *b > 4),
            "broker tier never co-scheduled: {brokers:?}"
        );
        for (p, b) in partitions.iter().zip(&brokers) {
            assert!(
                *p <= *b * 12,
                "window serves {p} partitions on {b} brokers (budget 12/node)"
            );
        }
    }

    #[test]
    fn elasticity_rackfail_puts_the_fault_lifecycle_on_the_timeline() {
        let config = cfg(CostPreset::Calibrated);
        let costs = CostModel::calibrated_default();
        let csv = elasticity_rackfail(&config, &costs).to_csv();
        assert!(
            csv.lines()
                .next()
                .unwrap()
                .ends_with("lost_msgs,truncated_records,reassignments"),
            "fault columns missing: {csv}"
        );
        assert_eq!(csv.lines().count(), 1 + 30, "one row per window");
        let col = |n: usize| -> Vec<f64> {
            csv.lines()
                .skip(1)
                .map(|l| l.split(',').nth(n).unwrap().parse().unwrap())
                .collect()
        };
        // Window 5: the rack dies (tier halves, Leader-ack tail lost);
        // window 7: the bounce re-joins (tails truncated) and the
        // reassignment pass re-spreads the crowded sets — once.
        let brokers = col(4);
        assert_eq!(brokers[5], 2.0, "the rack never died");
        assert_eq!(brokers[7], 4.0, "the bounce never returned");
        let lost = col(8);
        assert_eq!(lost[5], 1200.0);
        assert_eq!(lost.iter().sum::<f64>(), 1200.0);
        let truncated = col(9);
        assert_eq!(truncated[7], 1200.0);
        assert_eq!(truncated.iter().sum::<f64>(), 1200.0);
        let reassigned = col(10);
        assert_eq!(reassigned[7], 48.0);
        assert_eq!(reassigned.iter().sum::<f64>(), 48.0);
    }

    #[test]
    fn headline_matches_paper_scale() {
        let config = cfg(CostPreset::PaperEra);
        let costs = CostModel::paper_era();
        let csv = headline(&config, &costs).to_csv();
        assert!(csv.contains("1536"), "{csv}");
        // Producer MB/s should be in the paper's ballpark (~390 MB/s).
        let line = csv.lines().nth(1).unwrap();
        let mb: f64 = line.split(',').nth(2).unwrap().parse().unwrap();
        assert!(
            (250.0..600.0).contains(&mb),
            "headline producer throughput {mb} MB/s (paper ~390)"
        );
    }
}
