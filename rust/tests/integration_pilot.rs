//! Pilot-service integration: multi-framework deployments, dynamic
//! scaling across framework kinds, resource accounting under churn.

use pilot_streaming::cluster::Machine;
use pilot_streaming::pilot::{
    DaskDescription, FlinkDescription, FrameworkKind, KafkaDescription, PilotComputeDescription,
    PilotComputeService, PilotState, SparkDescription,
};
use pilot_streaming::saga::{LocalAdaptor, SimSlurmAdaptor};
use std::sync::Arc;

#[test]
fn full_streaming_landscape_on_one_machine() {
    // The paper's §6.5 deployment shape: broker + producer + processing
    // pilots side by side on one machine, each independently sized.
    let service = PilotComputeService::new(Machine::unthrottled(8));
    let (kafka, cluster) = service.start_kafka(KafkaDescription::new(2)).unwrap();
    let (dask, producers) = service.start_dask(DaskDescription::new(2)).unwrap();
    let (spark, engine) = service.start_spark(SparkDescription::new(2)).unwrap();
    assert_eq!(service.machine().free_nodes(), 2);
    assert_eq!(service.pilots().len(), 3);

    // All three frameworks usable concurrently.
    cluster.create_topic("x", 4).unwrap();
    cluster.produce("x", 0, 0, &[vec![1, 2, 3]]).unwrap();
    let f = producers.submit(|_| 40 + 2).unwrap();
    assert_eq!(f.wait().unwrap(), 42);
    assert!(engine.executor_count() > 0);

    for p in [&spark, &dask, &kafka] {
        service.stop_pilot(p).unwrap();
    }
    assert_eq!(service.machine().free_nodes(), 8);
    assert!(service.pilots().is_empty());
}

#[test]
fn startup_breakdown_ordering_matches_fig6() {
    // Live pilots record the same bootstrap models Fig 6 plots.
    let service = PilotComputeService::new(Machine::unthrottled(16));
    let mut totals = std::collections::HashMap::new();
    for (kind, nodes) in [
        (FrameworkKind::Kafka, 4usize),
        (FrameworkKind::Spark, 4),
        (FrameworkKind::Dask, 4),
        (FrameworkKind::Flink, 4),
    ] {
        let pilot = service
            .create_pilot(PilotComputeDescription::new("slurm://wrangler", kind, nodes))
            .unwrap();
        totals.insert(kind, pilot.startup().unwrap().total_secs());
        service.stop_pilot(&pilot).unwrap();
    }
    assert!(totals[&FrameworkKind::Kafka] > totals[&FrameworkKind::Spark]);
    assert!(totals[&FrameworkKind::Spark] > totals[&FrameworkKind::Dask]);
    assert!(totals[&FrameworkKind::Flink] > totals[&FrameworkKind::Dask]);
}

#[test]
fn repeated_extend_shrink_cycles_are_leak_free() {
    let service = PilotComputeService::new(Machine::unthrottled(8));
    let (parent, engine) = service
        .start_dask(DaskDescription::new(2).with_config("workers_per_node", "1"))
        .unwrap();
    for _ in 0..5 {
        let ext = service.extend_pilot(&parent, 3).unwrap();
        assert_eq!(service.machine().free_nodes(), 3);
        // Extension workers actually pull work.
        let futs: Vec<_> = (0..12)
            .map(|i| engine.submit(move |_| i).unwrap())
            .collect();
        for (i, f) in futs.into_iter().enumerate() {
            assert_eq!(f.wait().unwrap(), i);
        }
        service.stop_pilot(&ext).unwrap();
        assert_eq!(service.machine().free_nodes(), 6);
    }
    // Workers drained back to the base 2.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while engine.worker_count() != 2 && std::time::Instant::now() < deadline {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(engine.worker_count(), 2);
    service.stop_pilot(&parent).unwrap();
}

#[test]
fn kafka_extension_rebalances_partition_leaders() {
    let service = PilotComputeService::new(Machine::unthrottled(6));
    let (parent, cluster) = service.start_kafka(KafkaDescription::new(1)).unwrap();
    cluster.create_topic("t", 8).unwrap();
    let leaders_before: Vec<_> = (0..8).map(|p| cluster.leader_node("t", p).unwrap()).collect();
    assert!(leaders_before.iter().all(|l| *l == leaders_before[0]));

    let ext = service.extend_pilot(&parent, 3).unwrap();
    let leaders_after: Vec<_> = (0..8).map(|p| cluster.leader_node("t", p).unwrap()).collect();
    let distinct: std::collections::HashSet<_> = leaders_after.iter().collect();
    assert_eq!(distinct.len(), 4, "leaders spread over 4 brokers");

    // Data written before the rebalance is still readable.
    cluster.produce("t", 0, 5, &[vec![9]]).unwrap();
    let recs = cluster
        .fetch("t", 0, 0, usize::MAX, 5, std::time::Duration::from_millis(50))
        .unwrap();
    assert_eq!(recs.len(), 1);

    service.stop_pilot(&ext).unwrap();
    assert_eq!(cluster.broker_nodes().len(), 1);
    service.stop_pilot(&parent).unwrap();
}

#[test]
fn adaptor_choice_affects_queue_wait() {
    let machine = Machine::unthrottled(4);
    let local = PilotComputeService::with_adaptor(
        machine.clone(),
        Arc::new(LocalAdaptor::new()),
        0.0,
    );
    let (p1, _) = local.start_kafka(KafkaDescription::new(1)).unwrap();
    assert_eq!(p1.startup().unwrap().queue_wait_secs, 0.0, "fork adaptor");

    let slurm = PilotComputeService::with_adaptor(machine, SimSlurmAdaptor::wrangler(0.0), 0.0);
    let (p2, _) = slurm.start_kafka(KafkaDescription::new(1)).unwrap();
    assert!(p2.startup().unwrap().queue_wait_secs > 0.0, "slurm queue");
    local.stop_pilot(&p1).unwrap();
    slurm.stop_pilot(&p2).unwrap();
}

#[test]
fn failed_pilot_does_not_leak_nodes() {
    let service = PilotComputeService::new(Machine::unthrottled(2));
    let (ok, _) = service.start_kafka(KafkaDescription::new(2)).unwrap();
    // Machine is now full: next pilot fails...
    let err = service.create_pilot(FlinkDescription::new(1)).unwrap_err();
    assert!(err.to_string().contains("free"));
    // ...without leaking, and the failed pilot isn't registered.
    assert_eq!(service.pilots().len(), 1);
    service.stop_pilot(&ok).unwrap();
    assert_eq!(service.machine().free_nodes(), 2);
    // And the machine is usable again.
    let (again, _) = service.start_dask(DaskDescription::new(2)).unwrap();
    assert_eq!(again.state(), PilotState::Running);
    service.stop_pilot(&again).unwrap();
}
