//! Whole-pipeline integration on the real plane: MASS -> broker ->
//! micro-batch engine -> MASA processors executing AOT artifacts.
//! Requires `make artifacts`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use pilot_streaming::cluster::Machine;
use pilot_streaming::engine::{MicroBatchEngine, TaskEngine};
use pilot_streaming::miniapp::{
    MasaApp, MasaConfig, MassConfig, MassSource, ProcessorKind, SourceKind,
};
use pilot_streaming::pilot::{
    DaskDescription, KafkaDescription, PilotComputeService, SparkDescription,
};
use pilot_streaming::runtime::ModelRuntime;

/// AOT artifacts (`make artifacts`) plus the `xla` cargo feature are
/// prerequisites for the live compute plane; without them these
/// pipeline tests skip so plain `cargo test` stays green.
fn runtime() -> Option<ModelRuntime> {
    let rt = ModelRuntime::load_default().ok()?;
    if rt.warmup("gridrec").is_err() {
        eprintln!("skipping: PJRT executor unavailable (xla feature off)");
        return None;
    }
    Some(rt)
}

fn drain(job: &pilot_streaming::engine::StreamingJobHandle, expect: u64, secs: u64) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while job.stats().processed.messages() < expect && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(50));
    }
}

#[test]
fn kmeans_pipeline_conserves_messages_and_learns() {
    let Some(rt) = runtime() else { return };
    let k = rt.manifest().kmeans.k;
    let machine = Machine::unthrottled(4);
    let cluster = pilot_streaming::broker::BrokerCluster::new(machine.clone(), vec![0]);
    cluster.create_topic("km", 3).unwrap();
    let producers = TaskEngine::new(machine.clone(), vec![1], 2);
    let engine = MicroBatchEngine::new(machine, vec![2, 3], 1);

    let masa = MasaApp::new(
        MasaConfig::new(ProcessorKind::KMeans, "km", Duration::from_millis(100)),
        rt,
    );
    masa.processor.warmup().unwrap();
    let job = masa.start(&engine, cluster.clone()).unwrap();

    let mut cfg = MassConfig::new(SourceKind::KmeansRandom { n_centroids: k }, "km");
    cfg.messages_per_producer = 6;
    let report = MassSource::new(cfg).run(&producers, &cluster, 2).unwrap();
    assert_eq!(report.messages, 12);

    drain(&job, 12, 120);
    let stats = job.stop();
    assert_eq!(stats.processed.messages(), 12, "message conservation");
    assert_eq!(masa.processor.stats.errors.load(std::sync::atomic::Ordering::Relaxed), 0);

    let model = masa.processor.model();
    assert_eq!(model.updates, 12, "one model update per message");
    // The decayed updates must pull inertia down as the model locks on.
    assert!(
        model.last_inertia < 1e6,
        "inertia {} did not drop",
        model.last_inertia
    );
    engine.stop();
    producers.stop();
}

#[test]
fn gridrec_pipeline_via_pilot_service() {
    let Some(rt) = runtime() else { return };
    let template = Arc::new(rt.read_f32_file("template_sinogram.bin").unwrap());
    let service = PilotComputeService::new(Machine::unthrottled(6));
    let (kafka, cluster) = service.start_kafka(KafkaDescription::new(1)).unwrap();
    let (dask, producers) = service
        .start_dask(DaskDescription::new(1).with_config("workers_per_node", "2"))
        .unwrap();
    let (spark, engine) = service
        .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
        .unwrap();
    cluster.create_topic("aps", 2).unwrap();

    let masa = MasaApp::new(
        MasaConfig::new(ProcessorKind::GridRec, "aps", Duration::from_millis(150)),
        rt.clone(),
    );
    masa.processor.warmup().unwrap();
    let job = masa.start(&engine, cluster.clone()).unwrap();

    let mut cfg = MassConfig::new(SourceKind::Lightsource { template }, "aps");
    cfg.messages_per_producer = 3;
    let report = MassSource::new(cfg).run(&producers, &cluster, 2).unwrap();
    assert_eq!(report.messages, 6);
    // 2 MB padded messages on the wire.
    assert_eq!(report.bytes, 6 * 2_000_000);

    drain(&job, 6, 300);
    let stats = job.stop();
    assert_eq!(stats.processed.messages(), 6);
    let img = masa.processor.last_image();
    assert_eq!(img.len(), rt.manifest().tomo.img_h * rt.manifest().tomo.img_w);
    assert!(img.iter().any(|v| *v > 0.1), "reconstruction has structure");

    service.stop_pilot(&spark).unwrap();
    service.stop_pilot(&dask).unwrap();
    service.stop_pilot(&kafka).unwrap();
}

#[test]
fn pipeline_survives_mid_stream_extension() {
    let Some(rt) = runtime() else { return };
    let k = rt.manifest().kmeans.k;
    let service = PilotComputeService::new(Machine::unthrottled(6));
    let (kafka, cluster) = service.start_kafka(KafkaDescription::new(1)).unwrap();
    let (dask, producers) = service
        .start_dask(DaskDescription::new(1).with_config("workers_per_node", "2"))
        .unwrap();
    let (spark, engine) = service
        .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
        .unwrap();
    cluster.create_topic("km2", 4).unwrap();

    let masa = MasaApp::new(
        MasaConfig::new(ProcessorKind::KMeans, "km2", Duration::from_millis(100)),
        rt,
    );
    masa.processor.warmup().unwrap();
    let job = masa.start(&engine, cluster.clone()).unwrap();

    // Produce on a background thread while we extend the spark pilot.
    let producer_thread = {
        let cluster = cluster.clone();
        let producers = producers.clone();
        std::thread::spawn(move || {
            let mut cfg = MassConfig::new(SourceKind::KmeansRandom { n_centroids: k }, "km2");
            cfg.messages_per_producer = 8;
            MassSource::new(cfg).run(&producers, &cluster, 2).unwrap()
        })
    };
    std::thread::sleep(Duration::from_millis(100));
    let ext = service.extend_pilot(&spark, 2).unwrap();
    let report = producer_thread.join().unwrap();

    drain(&job, report.messages, 180);
    let stats = job.stop();
    assert_eq!(stats.processed.messages(), report.messages);

    service.stop_pilot(&ext).unwrap();
    service.stop_pilot(&spark).unwrap();
    service.stop_pilot(&dask).unwrap();
    service.stop_pilot(&kafka).unwrap();
}

#[test]
fn app_builder_runs_the_masa_pipeline() {
    // Builder-level coverage of the same pipeline the hand-wired tests
    // above assemble: one StreamingApp spec, MASA KMeans as the stage
    // processor (its artifacts compiled by the launch-time warmup), and
    // the drain protocol instead of polling.
    use pilot_streaming::app::{SourceSpec, StageSpec, StreamingApp};
    use pilot_streaming::miniapp::MasaProcessor;

    let Some(rt) = runtime() else { return };
    let k = rt.manifest().kmeans.k;
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(4)));
    let processor = MasaProcessor::new(ProcessorKind::KMeans, rt);

    let app = StreamingApp::builder()
        .broker(KafkaDescription::new(1), &[("km-app", 3)])
        .source(
            SourceSpec::mass(MassConfig::new(
                SourceKind::KmeansRandom { n_centroids: k },
                "km-app",
            ))
            .with_producers(2)
            .with_total_messages(13),
        )
        .stage(
            StageSpec::new("kmeans", "km-app", processor.clone())
                .with_window(Duration::from_millis(100)),
        )
        .build()
        .unwrap();

    let handle = app.launch(&service).unwrap();
    // 13 over 2 producers: 7 + 6 — with_total_messages keeps the odd
    // message the old `total / producers` wiring dropped.
    let produced = handle.await_sources().unwrap();
    assert_eq!(produced[0].messages, 13);

    let report = handle.drain_and_stop().unwrap();
    assert!(report.drained);
    assert_eq!(report.processed_messages(), 13, "message conservation");
    assert_eq!(report.terminal_lag(), 0);
    assert_eq!(processor.model().updates, 13, "one model update per message");
    assert_eq!(service.machine().free_nodes(), 4, "all pilots released");
}

#[test]
fn table1_characterization_runs() {
    let Some(rt) = runtime() else { return };
    let rec = pilot_streaming::exp::table1(&rt).unwrap();
    let csv = rec.to_csv();
    assert!(csv.contains("kmeans"));
    assert!(csv.contains("lightsource-gridrec"));
    assert_eq!(csv.lines().count(), 3, "header + 2 workloads: {csv}");
}
