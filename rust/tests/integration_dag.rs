//! Dataflow-DAG integration on the real plane: the committed
//! `examples/app_dag.toml` (a 3-stage chain with one split/merge
//! branch) launches from TOML, runs end-to-end with zero record loss,
//! and drains topologically; and an induced hot branch triggers a
//! per-edge scale-up of *only* the overloaded stage, asserted on the
//! per-stage `ScalingTimeline`s and on the per-edge lag signals.

use std::sync::Arc;
use std::time::{Duration, Instant};

use pilot_streaming::app::{
    AutoscaleSpec, CountingProcessor, SourceSpec, SplitRoute, SplitSpec, StageSpec, StreamingApp,
    StreamingAppBuilder,
};
use pilot_streaming::autoscale::{SignalProbe, ThresholdPolicy};
use pilot_streaming::cluster::Machine;
use pilot_streaming::metrics::ScalingAction;
use pilot_streaming::miniapp::{MassConfig, SourceKind};
use pilot_streaming::pilot::{KafkaDescription, PilotComputeService};

fn wait_until(mut cond: impl FnMut() -> bool, secs: f64) -> bool {
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < secs {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    false
}

/// The committed example DAG spec launches from TOML and drains
/// topologically with zero record loss at every hop.
#[test]
fn example_dag_toml_runs_end_to_end_with_zero_loss() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/app_dag.toml");
    let text = std::fs::read_to_string(path).expect("committed example spec");
    let doc = pilot_streaming::util::toml::parse(&text).unwrap();
    let machine_nodes = doc
        .get("machine_nodes")
        .and_then(pilot_streaming::util::Json::as_usize)
        .unwrap();
    let app = StreamingAppBuilder::from_json(&doc).unwrap().build().unwrap();

    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(machine_nodes)));
    let handle = app.launch(&service).unwrap();
    let produced: u64 = handle
        .await_sources()
        .unwrap()
        .iter()
        .map(|r| r.messages)
        .sum();
    assert_eq!(produced, 24, "examples/app_dag.toml produces 24 messages");

    let report = handle.drain_and_stop().unwrap();
    assert!(report.drained, "topological drain timed out");
    let idx = |name: &str| {
        report
            .stages
            .iter()
            .position(|s| s.name == name)
            .unwrap_or_else(|| panic!("no stage report for '{name}'"))
    };
    let stage = |name: &str| &report.stages[idx(name)];

    // The report lists the nodes in topological order: the chain hop
    // before the split, the split before its branches, the branches
    // before the merge legs, the merge before the archive sink.
    assert!(idx("reconstruct") < idx("route"));
    assert!(idx("route") < idx("compress-hot") && idx("route") < idx("compress-cold"));
    assert!(idx("compress-hot") < idx("fan-in:hotc"));
    assert!(idx("compress-cold") < idx("fan-in:coldc"));
    assert!(idx("fan-in:hotc") < idx("archive") && idx("fan-in:coldc") < idx("archive"));

    // Zero loss, hop by hop: every hop re-emits 1:1, the split routes
    // each record to exactly one branch, and the merge fans both
    // branches back in — so every hop's totals conserve the 24.
    assert_eq!(stage("reconstruct").processed_messages, produced);
    assert_eq!(stage("reconstruct").emitted_messages, produced);
    assert_eq!(stage("route").processed_messages, produced);
    assert_eq!(stage("route").emitted_messages, produced);
    let branches = [stage("compress-hot"), stage("compress-cold")];
    assert_eq!(
        branches.iter().map(|s| s.processed_messages).sum::<u64>(),
        produced,
        "split must route every record to exactly one branch"
    );
    assert_eq!(
        branches.iter().map(|s| s.emitted_messages).sum::<u64>(),
        produced
    );
    let legs = [stage("fan-in:hotc"), stage("fan-in:coldc")];
    assert_eq!(legs.iter().map(|s| s.processed_messages).sum::<u64>(), produced);
    assert_eq!(legs.iter().map(|s| s.emitted_messages).sum::<u64>(), produced);
    assert_eq!(stage("archive").processed_messages, produced, "end-to-end loss");
    assert_eq!(stage("archive").emitted_messages, 0, "the sink emits nothing");
    for s in &report.stages {
        assert_eq!(s.lag, 0, "stage '{}' drained with residual lag", s.name);
        assert_eq!(s.errors, 0, "stage '{}' errored", s.name);
    }
    assert_eq!(
        report.emitted_messages(),
        produced * 4,
        "reconstruct + route + branches + merge each re-emit the stream once"
    );
    assert_eq!(service.machine().free_nodes(), machine_nodes, "pilots leaked");
}

/// Uneven branch load becomes a *per-edge* planner intent: a predicate
/// split steers every record onto the hot branch, whose slow consumer
/// builds lag on its edge alone — its autoscaler scales up while the
/// cold branch's autoscaler (same policy, same thresholds) never moves.
#[test]
fn hot_branch_scales_up_alone() {
    let window = Duration::from_millis(30);
    let mut cfg = MassConfig::new(SourceKind::KmeansStatic, "in");
    cfg.points_per_msg = 50;
    cfg.target_msg_bytes = Some(0);
    let policy = || {
        ThresholdPolicy::new(15, 1)
            .with_sustain(2)
            .with_cooldown_secs(0.3)
    };
    let app = StreamingApp::builder()
        .broker(
            KafkaDescription::new(1),
            &[("in", 2), ("hot", 4), ("cold", 2)],
        )
        .source(
            SourceSpec::mass(cfg)
                .with_name("gen")
                .with_producers(2)
                .with_total_messages(120)
                .with_rate(200.0),
        )
        // Everything lands on branch 0: the hot edge carries the full
        // stream while the cold edge stays empty.
        .split(
            SplitSpec::new(
                "route",
                "in",
                &["hot", "cold"],
                SplitRoute::Predicate(Arc::new(|_| 0)),
            )
            .with_key_bytes(1)
            .with_window(window),
        )
        // 30 ms/message on one executor absorbs ~33 msg/s of a
        // 200 msg/s burst: the hot edge must build lag.
        .stage(
            StageSpec::new("slow-hot", "hot", CountingProcessor::with_cost(
                Duration::from_millis(30),
            ))
            .with_executors_per_node(1)
            .with_window(window),
        )
        .stage(
            StageSpec::new("idle-cold", "cold", CountingProcessor::new())
                .with_executors_per_node(1)
                .with_window(window),
        )
        .autoscale(
            AutoscaleSpec::for_stage("slow-hot", policy())
                .with_sample_interval(Duration::from_millis(50)),
        )
        .autoscale(
            AutoscaleSpec::for_stage("idle-cold", policy())
                .with_sample_interval(Duration::from_millis(50)),
        )
        .drain_timeout(Duration::from_secs(120))
        .build()
        .unwrap();

    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(10)));
    let handle = app.launch(&service).unwrap();
    let cluster = handle.cluster().clone();

    // The per-edge signals see the skew directly: the hot edge's lag
    // climbs while the cold edge reads zero from the same snapshot.
    let probe = SignalProbe::new(
        cluster.clone(),
        "hot",
        "app-slow-hot",
        handle.stage_stats("slow-hot"),
        0.05,
    )
    .with_edges(vec![
        ("hot".to_string(), "app-slow-hot".to_string()),
        ("cold".to_string(), "app-idle-cold".to_string()),
    ]);
    let edge = |snap: &pilot_streaming::autoscale::SignalSnapshot, topic: &str| {
        snap.edge_lags
            .iter()
            .find(|e| e.topic == topic)
            .map(|e| e.lag)
            .unwrap_or_else(|| panic!("no edge sample for '{topic}'"))
    };
    assert!(
        wait_until(
            || {
                let snap = probe.sample().unwrap();
                edge(&snap, "hot") >= 15 && edge(&snap, "cold") == 0
            },
            30.0
        ),
        "hot-edge lag never climbed past the threshold with the cold edge idle"
    );

    // The hot stage's autoscale loop reacts to its own edge...
    let hot_timeline = handle.timeline("slow-hot").expect("scaler registered");
    assert!(
        wait_until(|| hot_timeline.count(ScalingAction::Up) >= 1, 30.0),
        "the overloaded branch never scaled up; lag={:?}",
        cluster.group_lag("app-slow-hot", "hot")
    );
    // ...and only that loop: the cold branch saw nothing worth scaling.
    let cold_timeline = handle.timeline("idle-cold").expect("scaler registered");
    assert_eq!(
        cold_timeline.count(ScalingAction::Up),
        0,
        "per-edge scaling leaked onto the idle branch"
    );

    handle.await_sources().unwrap();
    let report = handle.drain_and_stop().unwrap();
    assert!(report.drained, "drain timed out");
    let hot = report.stages.iter().find(|s| s.name == "slow-hot").unwrap();
    let cold = report.stages.iter().find(|s| s.name == "idle-cold").unwrap();
    assert_eq!(hot.processed_messages, 120, "hot branch lost records");
    assert_eq!(cold.processed_messages, 0, "the predicate leaked records cold");
    assert_eq!(
        cold_timeline.count(ScalingAction::Up),
        0,
        "idle branch scaled during the drain"
    );
    assert_eq!(service.machine().free_nodes(), 10, "pilots leaked");
}
