//! Broker integration: throttled data plane, concurrent clients,
//! ordering and bandwidth-saturation behaviour.

use std::sync::Arc;
use std::time::{Duration, Instant};

use pilot_streaming::broker::{
    copytrack, AckMode, BrokerCluster, Consumer, ConsumerConfig, LogConfig, Partitioner,
    Producer, ProducerConfig, ReplicationConfig,
};
use pilot_streaming::cluster::Machine;
use pilot_streaming::config::MachineConfig;

fn throttled_machine(nodes: usize, nic_mbps: f64, ssd_mbps: f64) -> Machine {
    Machine::new(MachineConfig {
        name: "itest".into(),
        nodes,
        cores_per_node: 4,
        mem_gb_per_node: 8,
        nic_mbps,
        ssd_mbps,
    })
    .unwrap()
}

#[test]
fn per_partition_ordering_under_concurrency() {
    let machine = Machine::unthrottled(4);
    let cluster = BrokerCluster::new(machine, vec![0]);
    cluster.create_topic("ord", 2).unwrap();

    // Two producer threads target distinct partitions.
    let mut handles = Vec::new();
    for p in 0..2usize {
        let c = cluster.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..200u32 {
                c.produce("ord", p, 1, &[i.to_le_bytes().to_vec()]).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // Each partition's log preserves the producer's order exactly.
    for p in 0..2 {
        let recs = cluster
            .fetch("ord", p, 0, usize::MAX, 2, Duration::from_millis(10))
            .unwrap();
        assert_eq!(recs.len(), 200);
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.offset, i as u64);
            assert_eq!(u32::from_le_bytes(r.value[..4].try_into().unwrap()), i as u32);
        }
    }
}

#[test]
fn concurrent_group_consumers_partition_the_stream() {
    let machine = Machine::unthrottled(4);
    let cluster = BrokerCluster::new(machine, vec![0]);
    cluster.create_topic("shared", 4).unwrap();
    for i in 0..100u32 {
        cluster
            .produce("shared", (i % 4) as usize, 1, &[i.to_le_bytes().to_vec()])
            .unwrap();
    }
    let mut handles = Vec::new();
    for member in 0..2 {
        let c = cluster.clone();
        handles.push(std::thread::spawn(move || {
            let mut consumer = Consumer::join(
                c,
                "shared",
                "g",
                2 + member,
                ConsumerConfig {
                    fetch_timeout: Duration::from_millis(20),
                    ..Default::default()
                },
            )
            .unwrap();
            let mut got = Vec::new();
            let deadline = Instant::now() + Duration::from_secs(10);
            while Instant::now() < deadline {
                let recs = consumer.poll().unwrap();
                for r in &recs {
                    got.push(u32::from_le_bytes(r.record.value[..4].try_into().unwrap()));
                }
                // A stable 2-member group over 4 partitions sees half.
                if got.len() >= 50 {
                    break;
                }
            }
            got
        }));
    }
    let mut all: Vec<u32> = handles
        .into_iter()
        .flat_map(|h| h.join().unwrap())
        .collect();
    all.sort();
    all.dedup();
    assert_eq!(all.len(), 100, "every message consumed exactly once");
}

#[test]
fn nic_throttle_bounds_producer_throughput() {
    // Broker node NIC at 50 MB/s: pushing 20 MB must take >= ~0.3 s
    // (minus burst allowance).
    let machine = throttled_machine(2, 50.0, 1000.0);
    let cluster = BrokerCluster::new(machine, vec![0]);
    cluster.create_topic("tp", 1).unwrap();
    let payload = vec![0u8; 1 << 20]; // 1 MB
    let start = Instant::now();
    for _ in 0..20 {
        cluster.produce("tp", 0, 1, &[payload.clone()]).unwrap();
    }
    let secs = start.elapsed().as_secs_f64();
    let rate = 20.0 / secs;
    assert!(
        rate < 75.0,
        "throughput {rate:.0} MB/s exceeds the 50 MB/s NIC model"
    );
}

#[test]
fn more_broker_nodes_raise_aggregate_bandwidth() {
    // Same offered load, 1 vs 2 broker nodes with 40 MB/s disks:
    // round-robin partitions spread appends over both disks.
    let run = |brokers: usize| -> f64 {
        let machine = throttled_machine(brokers + 1, 10_000.0, 40.0);
        let nodes: Vec<usize> = (0..brokers).collect();
        let cluster = BrokerCluster::new(machine, nodes);
        cluster.create_topic("bw", brokers * 2).unwrap();
        let payload = vec![0u8; 1 << 20];
        let start = Instant::now();
        let mut handles = Vec::new();
        for t in 0..2 {
            let c = cluster.clone();
            let pl = payload.clone();
            let parts = brokers * 2;
            handles.push(std::thread::spawn(move || {
                for i in 0..8 {
                    c.produce("bw", (t * 8 + i) % parts, brokers, &[pl.clone()])
                        .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        16.0 / start.elapsed().as_secs_f64()
    };
    let one = run(1);
    let two = run(2);
    assert!(
        two > one * 1.4,
        "2 brokers {two:.0} MB/s should beat 1 broker {one:.0} MB/s"
    );
}

#[test]
fn producer_batching_amortizes_under_throttle() {
    let machine = throttled_machine(2, 200.0, 1000.0);
    let cluster = BrokerCluster::new(machine, vec![0]);
    cluster.create_topic("batch", 2).unwrap();
    let mut producer = Producer::new(
        cluster.clone(),
        "batch",
        1,
        ProducerConfig {
            batch_bytes: 256 << 10,
            linger: Duration::from_millis(500),
            partitioner: Partitioner::RoundRobin,
        },
    )
    .unwrap();
    for _ in 0..64 {
        producer.send(None, vec![0u8; 8 << 10]).unwrap();
    }
    producer.flush().unwrap();
    assert_eq!(producer.metrics.messages(), 64);
    let total: u64 = (0..2)
        .map(|p| cluster.end_offset("batch", p).unwrap())
        .sum();
    assert_eq!(total, 64);
}

#[test]
fn fetch_range_straddling_retention_eviction_errors_cleanly() {
    // Regression (bugfix-by-construction): consuming a range whose start
    // fell behind retention must return a clean broker Error — not a
    // panic, not silently skipped data — on both the direct log read
    // path and the cluster fetch path.
    let machine = Machine::unthrottled(2);
    let cluster = BrokerCluster::with_log_config(
        machine,
        vec![0],
        LogConfig {
            segment_bytes: 4 << 10,
            retention_bytes: Some(16 << 10),
        },
    );
    cluster.create_topic("gc", 1).unwrap();
    // Overflow retention: offset 0's segment gets evicted.
    for i in 0..32u32 {
        cluster
            .produce("gc", 0, 1, &[vec![i as u8; 2 << 10]])
            .unwrap();
    }
    let end = cluster.end_offset("gc", 0).unwrap();
    assert_eq!(end, 32);
    // A consumer that committed offset 0 long ago now asks for a range
    // straddling the evicted segments.
    let err = cluster
        .fetch("gc", 0, 0, usize::MAX, 1, Duration::from_millis(10))
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("retention"), "diagnosable error: {msg}");
    // The tail past the eviction horizon is fully readable, and the
    // records read back intact.
    let recs = cluster
        .fetch("gc", 0, end - 4, usize::MAX, 1, Duration::from_millis(10))
        .unwrap();
    assert_eq!(recs.len(), 4);
    assert_eq!(recs[0].value, vec![28u8; 2 << 10]);
}

#[test]
fn fetch_path_is_zero_copy_end_to_end() {
    // Acceptance: zero per-record payload copies on the fetch path,
    // asserted via the debug-only copy counter.  Covers the full
    // produce → fetch → consumer-poll pipeline.
    let machine = Machine::unthrottled(3);
    let cluster = BrokerCluster::new(machine, vec![0]);
    cluster.create_topic("zc", 1).unwrap();
    for i in 0..8u8 {
        cluster.produce("zc", 0, 1, &[vec![i; 32 << 10]]).unwrap();
    }
    let before = copytrack::payload_copies();
    let recs = cluster
        .fetch("zc", 0, 0, usize::MAX, 2, Duration::from_millis(10))
        .unwrap();
    assert_eq!(recs.len(), 8);
    let mut consumer = Consumer::join(
        cluster.clone(),
        "zc",
        "g",
        2,
        ConsumerConfig {
            fetch_timeout: Duration::from_millis(10),
            ..Default::default()
        },
    )
    .unwrap();
    let mut polled = 0;
    for _ in 0..16 {
        polled += consumer.poll().unwrap().len();
        if polled == 8 {
            break;
        }
    }
    assert_eq!(polled, 8);
    assert_eq!(
        copytrack::payload_copies(),
        before,
        "fetch/poll must hand out slab views, never copies"
    );
    // Sanity: the counter is live in debug builds.
    let owned = recs[0].value.to_vec();
    assert_eq!(owned.len(), 32 << 10);
    if cfg!(debug_assertions) {
        assert!(copytrack::payload_copies() > before);
    }
}

#[test]
fn leader_failover_mid_fetch_wakes_against_the_new_leader() {
    // A fetch blocked on the high watermark survives the leader's node
    // dying mid-wait: failover promotes the follower (which holds every
    // acked record via synchronous mirror adoption), and the next
    // produce — served by the new leader — wakes the fetcher.
    let machine = Machine::unthrottled(4);
    let cluster = BrokerCluster::new(machine, vec![0, 1]);
    cluster
        .create_topic_replicated("ft", 1, ReplicationConfig::new(2))
        .unwrap();
    cluster.produce("ft", 0, 2, &[vec![1u8]]).unwrap();

    // Block past the current watermark (offset 1 doesn't exist yet).
    let c = cluster.clone();
    let fetcher = std::thread::spawn(move || {
        c.fetch("ft", 0, 1, usize::MAX, 2, Duration::from_secs(10))
    });
    std::thread::sleep(Duration::from_millis(50));

    // Partition 0's leader is the first broker (round-robin placement).
    let victim = cluster.broker_nodes()[0];
    let report = cluster.kill_broker(victim).unwrap();
    assert_eq!(report.killed, victim);
    assert_eq!(report.promoted, 1, "the follower takes over partition 0");
    assert_eq!(report.unreplicated, 0, "factor 2 leaves no partition stranded");
    assert_eq!(cluster.broker_nodes(), vec![1]);

    // The record produced after the failover lands on the promoted
    // leader and reaches the still-blocked fetcher.
    cluster.produce("ft", 0, 2, &[vec![2u8]]).unwrap();
    let recs = fetcher.join().unwrap().unwrap();
    assert_eq!(recs.len(), 1);
    assert_eq!(recs[0].offset, 1);
    assert_eq!(recs[0].value, vec![2u8]);
}

#[test]
fn consumer_offsets_survive_node_death_and_quorum_rejects_degraded_produces() {
    // Group coordinator state is modeled as replicated: committed
    // offsets read back bit-identically across a broker death, so a
    // resuming consumer replays nothing.  Quorum acks meanwhile turn
    // the degraded replica set into produce *rejections* rather than
    // records a second death could lose.
    let machine = Machine::unthrottled(4);
    let cluster = BrokerCluster::new(machine, vec![0, 1]);
    cluster
        .create_topic_replicated(
            "dur",
            2,
            ReplicationConfig::new(2).with_ack_mode(AckMode::Quorum).with_min_insync(2),
        )
        .unwrap();
    cluster.group_join("g", "dur");
    for i in 0..5u8 {
        cluster.produce("dur", 0, 2, &[vec![i]]).unwrap();
        cluster.produce("dur", 1, 2, &[vec![i]]).unwrap();
    }
    cluster.commit("g", "dur", 0, 3);
    cluster.commit("g", "dur", 1, 5);
    assert_eq!(cluster.group_lag("g", "dur").unwrap(), 2);

    // Node 1 led partition 1 (round-robin placement); its follower on
    // node 0 is promoted.
    let report = cluster.kill_broker(cluster.broker_nodes()[1]).unwrap();
    assert_eq!(report.promoted, 1);
    assert_eq!(report.unreplicated, 0);

    // Offsets and lag are exactly what they were before the death.
    assert_eq!(cluster.committed("g", "dur", 0), 3);
    assert_eq!(cluster.committed("g", "dur", 1), 5);
    assert_eq!(cluster.group_lag("g", "dur").unwrap(), 2);

    // One alive replica < min_insync 2: quorum produces are refused.
    let err = cluster.produce("dur", 0, 2, &[vec![9u8]]).unwrap_err();
    assert!(
        err.to_string().contains("not enough in-sync replicas"),
        "diagnosable quorum rejection: {err}"
    );

    // A consumer resuming in the group drains exactly the 2 uncommitted
    // records — nothing lost to the death, nothing replayed.
    let mut consumer = Consumer::join(
        cluster.clone(),
        "dur",
        "g",
        2,
        ConsumerConfig {
            fetch_timeout: Duration::from_millis(10),
            ..Default::default()
        },
    )
    .unwrap();
    let mut got = Vec::new();
    for _ in 0..32 {
        for r in consumer.poll().unwrap() {
            got.push((r.partition, r.record.offset, r.record.value.to_vec()));
        }
        if got.len() >= 2 {
            break;
        }
    }
    got.sort();
    assert_eq!(got.len(), 2, "exactly the uncommitted tail: {got:?}");
    assert_eq!(got[0], (0, 3, vec![3u8]));
    assert_eq!(got[1], (0, 4, vec![4u8]));
    assert_eq!(cluster.group_lag("g", "dur").unwrap(), 0);

    // Healing the tier (the autoscaler's broker replacement landing)
    // restores quorum produces.
    cluster.add_brokers(vec![2]);
    cluster.produce("dur", 0, 2, &[vec![9u8]]).unwrap();
}

#[test]
fn cloud_broker_applies_latency_model() {
    use pilot_streaming::broker::cloud::{CloudBroker, CloudLatencyModel};
    let broker = CloudBroker::new(
        "test-fast",
        CloudLatencyModel {
            wan_secs: 0.005,
            mu: -4.0, // ~18 ms service
            sigma: 0.3,
        },
        9,
    );
    for i in 0..10u8 {
        broker.publish(vec![i]).unwrap();
    }
    let t0 = Instant::now();
    let mut got = Vec::new();
    while got.len() < 10 && t0.elapsed() < Duration::from_secs(5) {
        got.extend(broker.poll());
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(got.len(), 10);
    let mean: f64 = got.iter().map(|r| r.latency_secs()).sum::<f64>() / 10.0;
    assert!(mean > 0.01, "latency model applied: mean {mean}");
    let shared = Arc::new(broker);
    assert_eq!(shared.in_flight(), 0);
}

#[test]
fn blocking_fetch_on_quiesced_shard_errors_cleanly() {
    // Regression: a blocking fetch that parked while its shard was
    // quiesced for an epoch seal used to sleep its entire deadline (or
    // forever with a long one) — the sealed shard's doorbell never rang
    // for it.  Now quiesced fetchers wait in bounded slices and, past
    // the grace window, surface a clean `Error::ShardQuiesced` the
    // consumer layer treats as transient.
    use pilot_streaming::broker::shard_of;
    use pilot_streaming::Error;

    let machine = Machine::unthrottled(2);
    let cluster = BrokerCluster::with_shards(machine, vec![0], LogConfig::default(), 2);
    cluster.create_topic("q", 8).unwrap();
    // Two partitions on *different* shards: the seal must be per-shard,
    // not cluster-wide.
    let sealed = (0..8).find(|&p| shard_of(p, 2) == 0).unwrap();
    let open = (0..8).find(|&p| shard_of(p, 2) == 1).unwrap();
    assert_eq!(cluster.quiesce_partition_shard("q", sealed).unwrap(), 0);

    // A short-deadline fetch still times out to Ok(empty): quiescence
    // only converts waits that outlive the grace window into errors.
    let recs = cluster
        .fetch("q", sealed, 0, usize::MAX, 1, Duration::from_millis(20))
        .unwrap();
    assert!(recs.is_empty());

    // The long blocking fetch errors after the bounded grace window —
    // far before its 30 s deadline.
    let t0 = Instant::now();
    let err = cluster
        .fetch("q", sealed, 0, usize::MAX, 1, Duration::from_secs(30))
        .unwrap_err();
    assert!(matches!(err, Error::ShardQuiesced(_)), "{err}");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "bounded wait, not the full deadline: {:?}",
        t0.elapsed()
    );

    // The sibling shard keeps serving blocking fetches throughout.
    let c = cluster.clone();
    let h = std::thread::spawn(move || {
        c.fetch("q", open, 0, usize::MAX, 1, Duration::from_secs(5))
    });
    std::thread::sleep(Duration::from_millis(20));
    cluster.produce("q", open, 1, &[vec![7u8]]).unwrap();
    assert_eq!(h.join().unwrap().unwrap().len(), 1);

    // Resume: parked fetches on the sealed shard flow again end-to-end.
    assert_eq!(cluster.resume_partition_shard("q", sealed).unwrap(), 0);
    let c = cluster.clone();
    let h = std::thread::spawn(move || {
        c.fetch("q", sealed, 0, usize::MAX, 1, Duration::from_secs(5))
    });
    std::thread::sleep(Duration::from_millis(20));
    cluster.produce("q", sealed, 1, &[vec![8u8]]).unwrap();
    assert_eq!(h.join().unwrap().unwrap().len(), 1);
}
