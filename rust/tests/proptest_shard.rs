//! Property-based invariants over the thread-per-core sharded data
//! plane.
//!
//! The shard layer owns every partition's wakeup path, so its safety
//! story is this suite: across random shard counts, quiesce/resume
//! pulses and produce/fetch/repartition interleavings we assert
//!
//! * **(a) mapping sanity** — [`shard_of`] is deterministic, in range,
//!   and jump-consistent: growing the shard count relocates partitions
//!   only *toward the new shards* (the property partition placement and
//!   epoch seals both lean on);
//! * **(b) no lost wakeups** — a blocking fetch never sleeps out a long
//!   deadline while unconsumed records sit in its partition, across
//!   concurrent producers on every shard and random epoch-seal-style
//!   quiesce/resume pulses (the store-buffer hazard the doorbell's
//!   SeqCst fence pair exists to kill);
//! * **(c) per-key order** — the exactly-once / per-key-order contract
//!   of the repartition suite still holds when the topic lives on a
//!   multi-shard cluster and seals quiesce only the owning shards.
//!
//! Like `proptest_invariants.rs`, this is a seeded-random harness (the
//! offline dependency set has no `proptest`): failures print the seed
//! for replay, and `PROPTEST_CASES` scales the case count (the CI
//! `proptest` job runs these suites deeper than the default
//! `cargo test` pass).  The thread-heavy wakeup property divides the
//! case count down — each case spawns a full producer/fetcher fleet.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use pilot_streaming::broker::{
    shard_of, BrokerCluster, Consumer, ConsumerConfig, LogConfig, PartitionRecord, Partitioner,
    Producer, ProducerConfig,
};
use pilot_streaming::cluster::Machine;
use pilot_streaming::util::Rng;
use pilot_streaming::Error;

/// Case count: `PROPTEST_CASES` env override, else the suite default.
fn cases(default: usize) -> usize {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Run `f` over exactly `n_cases` seeded cases; panic messages carry
/// the seed for replay.  (Callers pass [`cases`] through, divided down
/// for thread-heavy properties.)
fn check<F: Fn(&mut Rng)>(name: &str, n_cases: usize, f: F) {
    for case in 0..n_cases {
        let seed = 0xD00B311 ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {e:?}");
        }
    }
}

/// Invariant (a): the partition→shard map is total, stable, and moves
/// minimally (and only toward the new shards) when the shard count
/// grows — so a fleet resize never shuffles wakeup ownership of
/// partitions that didn't need to move.
#[test]
fn prop_shard_mapping_stable_in_range_minimal_movement() {
    check("shard-mapping", cases(300), |rng| {
        let n = 1 + rng.below(32);
        let m = n + 1 + rng.below(16);
        for p in 0..128 {
            let s = shard_of(p, n);
            assert!(s < n, "shard_of({p}, {n}) = {s} out of range");
            assert_eq!(s, shard_of(p, n), "shard_of not deterministic");
            let grown = shard_of(p, m);
            assert!(grown < m);
            if grown != s {
                assert!(
                    grown >= n,
                    "growing {n} -> {m} shards moved partition {p} to old shard {grown}"
                );
            }
        }
    });
}

/// Invariant (b): no lost wakeups.  One blocking fetcher tails each
/// partition with a deadline far longer than the whole workload while
/// one producer per partition appends through it, and the driver fires
/// random quiesce/resume pulses (what an epoch seal does to the owning
/// shard).  If any fetcher's blocking fetch returns empty while records
/// it has not consumed exist, a doorbell ring was lost.
#[test]
fn prop_no_lost_wakeups_across_produce_quiesce_interleavings() {
    check(
        "shard-no-lost-wakeups",
        (cases(200) / 20).clamp(3, 30),
        |rng| {
            let n_shards = 1 + rng.below(4);
            let parts = 1 + rng.below(6);
            let per: u64 = 20 + rng.below(40) as u64;
            let cluster = BrokerCluster::with_shards(
                Machine::unthrottled(2),
                vec![0],
                LogConfig::default(),
                n_shards,
            );
            cluster.create_topic("w", parts).unwrap();
            let stalled = Arc::new(AtomicBool::new(false));

            std::thread::scope(|s| {
                for p in 0..parts {
                    let cluster = cluster.clone();
                    let stalled = stalled.clone();
                    s.spawn(move || {
                        let mut pos = 0u64;
                        while pos < per {
                            match cluster.fetch(
                                "w",
                                p,
                                pos,
                                usize::MAX,
                                1,
                                Duration::from_secs(20),
                            ) {
                                Ok(recs) if recs.is_empty() => {
                                    // A 20 s blocking fetch timed out
                                    // mid-stream: the producer is still
                                    // appending (pos < per), so a ring
                                    // was lost.
                                    stalled.store(true, Ordering::Relaxed);
                                    return;
                                }
                                Ok(recs) => {
                                    assert_eq!(recs[0].offset, pos, "gap in partition {p}");
                                    pos = recs.last().unwrap().offset + 1;
                                }
                                // The driver may hold a quiesce past the
                                // grace window; transient by contract.
                                Err(Error::ShardQuiesced(_)) => continue,
                                Err(e) => panic!("fetch on partition {p}: {e}"),
                            }
                        }
                    });
                }
                for p in 0..parts {
                    let cluster = cluster.clone();
                    s.spawn(move || {
                        for i in 0..per {
                            cluster.produce("w", p, 1, &[vec![i as u8]]).unwrap();
                            if i % 7 == 0 {
                                std::thread::yield_now();
                            }
                        }
                    });
                }
                // Driver: epoch-seal-style pulses on random partitions'
                // shards while the fleet runs.
                for _ in 0..rng.below(6) {
                    let p = rng.below(parts);
                    cluster.quiesce_partition_shard("w", p).unwrap();
                    std::thread::sleep(Duration::from_millis(rng.below(3) as u64));
                    cluster.resume_partition_shard("w", p).unwrap();
                    std::thread::yield_now();
                }
            });

            assert!(
                !stalled.load(Ordering::Relaxed),
                "lost wakeup: a blocking fetch slept out its deadline with records pending \
                 ({n_shards} shards, {parts} partitions)"
            );
            for p in 0..parts {
                assert_eq!(cluster.end_offset("w", p).unwrap(), per);
            }
        },
    );
}

fn encode(key: usize, seq: u32) -> Vec<u8> {
    vec![
        key as u8,
        (seq >> 24) as u8,
        (seq >> 16) as u8,
        (seq >> 8) as u8,
        seq as u8,
    ]
}

fn decode(value: &[u8]) -> (usize, u32) {
    (
        value[0] as usize,
        u32::from_be_bytes([value[1], value[2], value[3], value[4]]),
    )
}

/// Invariant (b): each key's records arrive in dense produce order.
fn observe(recs: Vec<PartitionRecord>, consumed_seq: &mut [u32], consumed_total: &mut usize) {
    for r in recs {
        let (k, seq) = decode(&r.record.value);
        assert_eq!(
            seq, consumed_seq[k],
            "key {k}: expected seq {} next, saw {seq} (reorder/dup/loss)",
            consumed_seq[k]
        );
        consumed_seq[k] += 1;
        *consumed_total += 1;
    }
}

/// Invariant (c): the repartition suite's exactly-once + per-key-order
/// contract holds on a multi-shard cluster with quiesce/resume pulses
/// mixed into the interleaving — seals that stall one shard must not
/// reorder or lose records anywhere.
#[test]
fn prop_sharded_repartition_keeps_exactly_once_per_key_order() {
    check("sharded-repartition-order", (cases(200) / 10).clamp(5, 40), |rng| {
        let n_keys = 2 + rng.below(6);
        let n_shards = 1 + rng.below(4);
        let cluster = BrokerCluster::with_shards(
            Machine::unthrottled(4),
            vec![0],
            LogConfig::default(),
            n_shards,
        );
        cluster.create_topic("t", 1 + rng.below(4)).unwrap();

        let batch_bytes = if rng.below(2) == 0 { 1 } else { 24 };
        let mut producer = Producer::new(
            cluster.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes,
                partitioner: Partitioner::Keyed,
                ..Default::default()
            },
        )
        .unwrap();
        let config = ConsumerConfig {
            fetch_timeout: Duration::from_millis(1),
            ..Default::default()
        };
        let mut consumer = Consumer::join(cluster.clone(), "t", "g", 2, config).unwrap();

        let mut produced_seq = vec![0u32; n_keys];
        let mut consumed_seq = vec![0u32; n_keys];
        let mut produced_total = 0usize;
        let mut consumed_total = 0usize;

        let steps = 10 + rng.below(25);
        for _ in 0..steps {
            match rng.below(10) {
                0..=4 => {
                    for _ in 0..1 + rng.below(8) {
                        let k = rng.below(n_keys);
                        let seq = produced_seq[k];
                        produced_seq[k] += 1;
                        producer.send(Some(&[k as u8]), encode(k, seq)).unwrap();
                        produced_total += 1;
                    }
                    if rng.below(2) == 0 {
                        producer.flush().unwrap();
                    }
                }
                // Resize the topic mid-stream — the seal quiesces only
                // the owning shards.
                5 | 6 => {
                    cluster.repartition_topic("t", 1 + rng.below(8)).unwrap();
                }
                // A bare seal-style pulse with no resize.
                7 => {
                    let live = cluster.partition_count("t").unwrap();
                    let p = rng.below(live);
                    cluster.quiesce_partition_shard("t", p).unwrap();
                    cluster.resume_partition_shard("t", p).unwrap();
                }
                _ => {
                    for _ in 0..1 + rng.below(4) {
                        let recs = consumer.poll().unwrap();
                        observe(recs, &mut consumed_seq, &mut consumed_total);
                    }
                }
            }
        }

        producer.flush().unwrap();
        let mut idle_rounds = 0;
        while consumed_total < produced_total && idle_rounds < 300 {
            let recs = consumer.poll().unwrap();
            if recs.is_empty() {
                idle_rounds += 1;
            } else {
                idle_rounds = 0;
            }
            observe(recs, &mut consumed_seq, &mut consumed_total);
        }

        assert_eq!(
            consumed_total, produced_total,
            "exactly-once violated on {n_shards} shards: {consumed_total} of {produced_total}"
        );
        assert_eq!(consumed_seq, produced_seq, "per-key completeness");
        assert_eq!(cluster.group_lag("g", "t").unwrap(), 0);
    });
}
