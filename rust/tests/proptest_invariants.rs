//! Property-based tests over coordinator invariants.
//!
//! The offline dependency set has no `proptest`, so this file carries a
//! small seeded-random property harness (`props!`): each property runs
//! against many generated cases; failures print the seed for replay.

use pilot_streaming::broker::{BrokerCluster, LogConfig, PartitionLog};
use pilot_streaming::cluster::Machine;
use pilot_streaming::miniapp::{Message, PayloadKind};
use pilot_streaming::util::{Json, Rng};

/// Cases per property: `PROPTEST_CASES` env override (the CI `proptest`
/// job runs the invariant suites deeper), else 200.
fn cases() -> usize {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200)
}

/// Run `f` over seeded cases; panic messages carry the seed.
fn check<F: Fn(&mut Rng)>(name: &str, f: F) {
    for case in 0..cases() {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {e:?}");
        }
    }
}

// ---------------------------------------------------------------------
// Partition log invariants
// ---------------------------------------------------------------------

#[test]
fn prop_log_offsets_dense_and_values_roundtrip() {
    check("log-roundtrip", |rng| {
        let log = PartitionLog::new(LogConfig {
            segment_bytes: 1 + rng.below(64),
            retention_bytes: None,
        });
        let mut expect: Vec<Vec<u8>> = Vec::new();
        for _ in 0..rng.below(20) + 1 {
            let batch: Vec<Vec<u8>> = (0..rng.below(5) + 1)
                .map(|_| (0..rng.below(16)).map(|_| rng.below(256) as u8).collect())
                .collect();
            let base = log.append_batch(batch.iter().map(|v| v.as_slice()), 0);
            assert_eq!(base as usize, expect.len(), "dense offsets");
            expect.extend(batch);
        }
        // Full read returns exactly what was appended, in order.
        let recs = log.read(0, usize::MAX).unwrap();
        assert_eq!(recs.len(), expect.len());
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.offset, i as u64);
            assert_eq!(r.value, expect[i]);
        }
        // Random mid-log reads agree with the suffix.
        if !expect.is_empty() {
            let from = rng.below(expect.len());
            let recs = log.read(from as u64, usize::MAX).unwrap();
            assert_eq!(recs.len(), expect.len() - from);
            assert_eq!(recs[0].value, expect[from]);
        }
    });
}

#[test]
fn prop_log_retention_never_loses_tail() {
    check("log-retention", |rng| {
        let retention = 64 + rng.below(256);
        let log = PartitionLog::new(LogConfig {
            segment_bytes: 16 + rng.below(32),
            retention_bytes: Some(retention),
        });
        let mut total = 0u64;
        for _ in 0..rng.below(60) + 5 {
            let len = rng.below(24);
            let v: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            log.append_batch([v.as_slice()], 0);
            total += 1;
            // Invariants after every append:
            assert_eq!(log.end_offset(), total);
            assert!(log.start_offset() <= log.end_offset());
            // The newest record is always readable.
            let recs = log.read(total - 1, usize::MAX).unwrap();
            assert_eq!(recs.len(), 1);
            assert_eq!(recs[0].value, v);
        }
    });
}

// ---------------------------------------------------------------------
// Consumer-group assignment invariants
// ---------------------------------------------------------------------

#[test]
fn prop_group_assignment_is_partition_of_topic() {
    check("group-partition", |rng| {
        let n_parts = 1 + rng.below(24);
        let cluster = BrokerCluster::new(Machine::unthrottled(1), vec![0]);
        cluster.create_topic("t", n_parts).unwrap();
        let n_members = 1 + rng.below(8);
        let members: Vec<u64> = (0..n_members)
            .map(|_| cluster.group_join("g", "t").0)
            .collect();
        // Randomly remove some members (never all).
        let mut live = members.clone();
        while live.len() > 1 && rng.below(2) == 0 {
            let idx = rng.below(live.len());
            let m = live.remove(idx);
            cluster.group_leave("g", "t", m);
        }
        // Union of assignments == all partitions, pairwise disjoint.
        let mut seen = vec![false; n_parts];
        for m in &live {
            let (_, parts) = cluster.group_assignment("g", "t", *m).unwrap();
            for p in parts {
                assert!(!seen[p], "partition {p} double-assigned");
                seen[p] = true;
            }
        }
        assert!(seen.iter().all(|s| *s), "all partitions covered: {seen:?}");
    });
}

// ---------------------------------------------------------------------
// Wire format invariants
// ---------------------------------------------------------------------

#[test]
fn prop_wire_roundtrip_any_payload() {
    check("wire-roundtrip", |rng| {
        let kind = if rng.below(2) == 0 {
            PayloadKind::KmeansPoints
        } else {
            PayloadKind::Sinogram
        };
        let n = rng.below(500);
        let values: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
        let msg = Message::new(kind, rng.next_u64(), rng.next_u64(), values);
        let target = rng.below(4096);
        let bytes = msg.encode(target);
        assert!(bytes.len() >= target.min(bytes.len()));
        let back = Message::decode(&bytes).unwrap();
        assert_eq!(back, msg);
    });
}

#[test]
fn prop_wire_decode_never_panics_on_garbage() {
    check("wire-garbage", |rng| {
        let n = rng.below(256);
        let bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        // Must return Ok or Err, never panic.
        let _ = Message::decode(&bytes);
        // Truncations of a valid message never panic either.
        let msg = Message::new(PayloadKind::Sinogram, 1, 2, vec![1.0; 8]);
        let full = msg.encode(64);
        let cut = rng.below(full.len());
        let _ = Message::decode(&full[..cut]);
    });
}

// ---------------------------------------------------------------------
// JSON invariants
// ---------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num((rng.gauss() * 1e3).round()),
        3 => {
            let n = rng.below(12);
            Json::Str(
                (0..n)
                    .map(|_| char::from_u32(32 + rng.below(90) as u32).unwrap())
                    .collect(),
            )
        }
        4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => {
            let mut obj = Json::obj();
            for i in 0..rng.below(4) {
                obj = obj.set(&format!("k{i}"), random_json(rng, depth - 1));
            }
            obj
        }
    }
}

#[test]
fn prop_json_display_parse_roundtrip() {
    check("json-roundtrip", |rng| {
        let j = random_json(rng, 3);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(back, j, "roundtrip of {text}");
    });
}

#[test]
fn prop_json_parse_never_panics() {
    check("json-garbage", |rng| {
        let n = rng.below(64);
        let garbage: String = (0..n)
            .map(|_| char::from_u32(32 + rng.below(96) as u32).unwrap())
            .collect();
        let _ = Json::parse(&garbage); // Ok or Err, never panic
    });
}

// ---------------------------------------------------------------------
// Machine allocation invariants
// ---------------------------------------------------------------------

#[test]
fn prop_machine_allocations_disjoint_and_conserved() {
    check("machine-conservation", |rng| {
        let total = 4 + rng.below(12);
        let machine = Machine::unthrottled(total);
        let mut held: Vec<(String, usize)> = Vec::new();
        for step in 0..rng.below(20) + 1 {
            if rng.below(2) == 0 {
                let want = 1 + rng.below(4);
                let id = format!("p{step}");
                if let Ok(nodes) = machine.allocate(&id, want) {
                    assert_eq!(nodes.len(), want);
                    held.push((id, want));
                }
            } else if !held.is_empty() {
                let idx = rng.below(held.len());
                let (id, _) = held.remove(idx);
                machine.release(&id);
            }
            // Conservation: free + held == total.
            let held_count: usize = held.iter().map(|(_, n)| n).sum();
            assert_eq!(machine.free_nodes() + held_count, total);
            // Disjointness across live allocations.
            let allocs = machine.allocations();
            let mut seen = std::collections::HashSet::new();
            for a in &allocs {
                for n in &a.nodes {
                    assert!(seen.insert(*n), "node {n} in two allocations");
                }
            }
        }
    });
}
