//! Closed-loop elasticity on the real plane: a bursty MASS source
//! drives consumer lag up; the autoscaler must detect it, extend the
//! processing pilot, drain the backlog, and shrink back — with the full
//! cycle recorded on the metrics timeline and zero manual
//! `extend_pilot` calls.

use std::sync::Arc;
use std::time::{Duration, Instant};

use pilot_streaming::autoscale::{Autoscaler, AutoscalerConfig, ThresholdPolicy};
use pilot_streaming::broker::Record;
use pilot_streaming::cluster::Machine;
use pilot_streaming::engine::{StreamingJobConfig, TaskContext, TaskEngine};
use pilot_streaming::metrics::ScalingAction;
use pilot_streaming::miniapp::{MassConfig, MassSource, SourceKind};
use pilot_streaming::pilot::{KafkaDescription, PilotComputeService, SparkDescription};
use pilot_streaming::util::RateSchedule;

fn wait_until(mut cond: impl FnMut() -> bool, secs: f64) -> bool {
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < secs {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    false
}

#[test]
fn bursty_source_triggers_full_scale_cycle() {
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(6)));
    let (kafka, cluster) = service.start_kafka(KafkaDescription::new(1)).unwrap();
    let (spark, engine) = service
        .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
        .unwrap();
    cluster.create_topic("load", 4).unwrap();

    // A consumer that costs 20 ms/message: one executor absorbs
    // ~50 msg/s, so the 100 msg/s burst must build lag.
    let processor = |_: &TaskContext, recs: &[Record]| {
        std::thread::sleep(Duration::from_millis(20) * recs.len() as u32);
        Ok(())
    };
    let mut jc = StreamingJobConfig::new("load", Duration::from_millis(50));
    jc.group = "scaler".into();
    let job = engine
        .start_job(cluster.clone(), jc, Arc::new(processor))
        .unwrap();

    let scaler = Autoscaler::spawn(
        service.clone(),
        spark.clone(),
        cluster.clone(),
        Some(job.stats().clone()),
        Box::new(
            ThresholdPolicy::new(15, 1)
                .with_sustain(2)
                .with_cooldown_secs(0.3)
                .with_step(3),
        ),
        AutoscalerConfig::new("load", "scaler")
            .with_sample_interval(Duration::from_millis(50))
            .with_max_extension_nodes(3)
            .with_max_step(3)
            .with_window(Duration::from_millis(50)),
    );

    // Bursty simulated source: 1 s at 100 msg/s, then a 4 msg/s trickle.
    let producer_engine = TaskEngine::new(service.machine().clone(), vec![5], 1);
    let mut cfg = MassConfig::new(SourceKind::KmeansStatic, "load");
    cfg.points_per_msg = 50;
    cfg.target_msg_bytes = Some(0);
    cfg.messages_per_producer = 104;
    cfg.schedule = Some(RateSchedule::starting_at(1.0, 100.0).then(f64::INFINITY, 4.0));
    let report = MassSource::new(cfg).run(&producer_engine, &cluster, 1).unwrap();
    assert_eq!(report.messages, 104);

    let timeline = scaler.timeline();
    // Detection -> extend: the burst must have produced a scale-up.
    assert!(
        wait_until(|| timeline.count(ScalingAction::Up) >= 1, 30.0),
        "autoscaler never scaled up; lag={:?}",
        cluster.group_lag("scaler", "load")
    );
    // Drain -> shrink: lag goes to zero and the extensions are released.
    assert!(
        wait_until(
            || timeline.count(ScalingAction::Down) >= 1 && scaler.extension_count() == 0,
            60.0
        ),
        "autoscaler never scaled back down; lag={:?}",
        cluster.group_lag("scaler", "load")
    );
    assert!(
        wait_until(|| cluster.group_lag("scaler", "load").unwrap() == 0, 60.0),
        "backlog never drained"
    );

    // The ScalingEvent timeline must describe the whole cycle.
    let events = timeline.events();
    let first_up = events
        .iter()
        .position(|e| e.action == ScalingAction::Up)
        .unwrap();
    let first_down = events
        .iter()
        .position(|e| e.action == ScalingAction::Down)
        .unwrap();
    assert!(first_up < first_down, "up must precede down");
    let up = &events[first_up];
    assert!(up.lag >= 15, "scale-up lag {} below threshold", up.lag);
    assert!(up.delta_nodes >= 1 && up.total_nodes > 1);
    assert!(up.reaction_secs < 10.0, "reaction {}s", up.reaction_secs);
    assert_eq!(up.policy, "threshold");

    // Fleet is back at the base; the machine got its nodes back.
    let remaining = scaler.stop();
    assert!(remaining.is_empty(), "extensions left after scale-down");
    assert!(
        wait_until(|| engine.executor_count() == 1, 10.0),
        "executors did not drain to the base pilot"
    );
    // 6 total - kafka(1) - spark(1).
    assert_eq!(service.machine().free_nodes(), 4);

    job.stop();
    producer_engine.stop();
    service.stop_pilot(&spark).unwrap();
    service.stop_pilot(&kafka).unwrap();
}

#[test]
fn autoscaler_respects_extension_ceiling_and_machine_capacity() {
    // Machine with exactly one spare node: the policy may ask for 4 but
    // only one extension can materialize, and the loop must not error.
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(3)));
    let (kafka, cluster) = service.start_kafka(KafkaDescription::new(1)).unwrap();
    let (spark, engine) = service
        .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
        .unwrap();
    cluster.create_topic("t", 2).unwrap();

    let scaler = Autoscaler::spawn(
        service.clone(),
        spark.clone(),
        cluster.clone(),
        None,
        Box::new(ThresholdPolicy::new(5, 1).with_sustain(1).with_cooldown_secs(0.1).with_step(4)),
        AutoscalerConfig::new("t", "g")
            .with_sample_interval(Duration::from_millis(30))
            .with_max_extension_nodes(4)
            .with_max_step(4),
    );
    // Standing lag, nobody consuming.
    let batch: Vec<Vec<u8>> = (0..30u8).map(|i| vec![i]).collect();
    cluster.produce("t", 0, 0, &batch).unwrap();

    assert!(
        wait_until(|| scaler.extension_count() >= 1, 10.0),
        "no extension appeared"
    );
    // Give the loop time to (incorrectly) over-allocate; it can't: the
    // machine is full.
    std::thread::sleep(Duration::from_millis(300));
    assert_eq!(engine.executor_count(), 2, "1 base + the single spare node");
    assert_eq!(service.machine().free_nodes(), 0);

    for p in scaler.stop() {
        service.stop_pilot(&p).unwrap();
    }
    service.stop_pilot(&spark).unwrap();
    service.stop_pilot(&kafka).unwrap();
    assert_eq!(service.machine().free_nodes(), 3);
}
