//! Closed-loop elasticity on the real plane: a bursty MASS source
//! drives consumer lag up; the autoscaler must detect it, extend the
//! processing pilot, drain the backlog, and shrink back — with the full
//! cycle recorded on the metrics timeline and zero manual
//! `extend_pilot` calls.

use std::sync::Arc;
use std::time::{Duration, Instant};

use pilot_streaming::autoscale::{
    Autoscaler, AutoscalerConfig, PartitionElastic, PlannerConfig, ThresholdPolicy,
};
use pilot_streaming::broker::Record;
use pilot_streaming::cluster::Machine;
use pilot_streaming::engine::{StreamingJobConfig, TaskContext, TaskEngine};
use pilot_streaming::metrics::ScalingAction;
use pilot_streaming::miniapp::{MassConfig, MassSource, SourceKind};
use pilot_streaming::pilot::{KafkaDescription, PilotComputeService, SparkDescription};
use pilot_streaming::util::RateSchedule;

fn wait_until(mut cond: impl FnMut() -> bool, secs: f64) -> bool {
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < secs {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    false
}

#[test]
fn bursty_source_triggers_full_scale_cycle() {
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(6)));
    let (kafka, cluster) = service.start_kafka(KafkaDescription::new(1)).unwrap();
    let (spark, engine) = service
        .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
        .unwrap();
    cluster.create_topic("load", 4).unwrap();

    // A consumer that costs 20 ms/message: one executor absorbs
    // ~50 msg/s, so the 100 msg/s burst must build lag.
    let processor = |_: &TaskContext, recs: &[Record]| {
        std::thread::sleep(Duration::from_millis(20) * recs.len() as u32);
        Ok(())
    };
    let mut jc = StreamingJobConfig::new("load", Duration::from_millis(50));
    jc.group = "scaler".into();
    let job = engine
        .start_job(cluster.clone(), jc, Arc::new(processor))
        .unwrap();

    let scaler = Autoscaler::spawn(
        service.clone(),
        spark.clone(),
        cluster.clone(),
        Some(job.stats().clone()),
        Box::new(
            ThresholdPolicy::new(15, 1)
                .with_sustain(2)
                .with_cooldown_secs(0.3)
                .with_step(3),
        ),
        AutoscalerConfig::new("load", "scaler")
            .with_sample_interval(Duration::from_millis(50))
            .with_max_extension_nodes(3)
            .with_max_step(3)
            .with_window(Duration::from_millis(50)),
    );

    // Bursty simulated source: 1 s at 100 msg/s, then a 4 msg/s trickle.
    let producer_engine = TaskEngine::new(service.machine().clone(), vec![5], 1);
    let mut cfg = MassConfig::new(SourceKind::KmeansStatic, "load");
    cfg.points_per_msg = 50;
    cfg.target_msg_bytes = Some(0);
    cfg.messages_per_producer = 104;
    cfg.schedule = Some(RateSchedule::starting_at(1.0, 100.0).then(f64::INFINITY, 4.0));
    let report = MassSource::new(cfg).run(&producer_engine, &cluster, 1).unwrap();
    assert_eq!(report.messages, 104);

    let timeline = scaler.timeline();
    // Detection -> extend: the burst must have produced a scale-up.
    assert!(
        wait_until(|| timeline.count(ScalingAction::Up) >= 1, 30.0),
        "autoscaler never scaled up; lag={:?}",
        cluster.group_lag("scaler", "load")
    );
    // Drain -> shrink: lag goes to zero and the extensions are released.
    assert!(
        wait_until(
            || timeline.count(ScalingAction::Down) >= 1 && scaler.extension_count() == 0,
            60.0
        ),
        "autoscaler never scaled back down; lag={:?}",
        cluster.group_lag("scaler", "load")
    );
    assert!(
        wait_until(|| cluster.group_lag("scaler", "load").unwrap() == 0, 60.0),
        "backlog never drained"
    );

    // The ScalingEvent timeline must describe the whole cycle.
    let events = timeline.events();
    let first_up = events
        .iter()
        .position(|e| e.action == ScalingAction::Up)
        .unwrap();
    let first_down = events
        .iter()
        .position(|e| e.action == ScalingAction::Down)
        .unwrap();
    assert!(first_up < first_down, "up must precede down");
    let up = &events[first_up];
    assert!(up.lag >= 15, "scale-up lag {} below threshold", up.lag);
    assert!(up.delta_nodes >= 1 && up.total_nodes > 1);
    assert!(up.reaction_secs < 10.0, "reaction {}s", up.reaction_secs);
    assert_eq!(up.policy, "threshold");

    // Fleet is back at the base; the machine got its nodes back.
    let remaining = scaler.stop();
    assert!(remaining.is_empty(), "extensions left after scale-down");
    assert!(
        wait_until(|| engine.executor_count() == 1, 10.0),
        "executors did not drain to the base pilot"
    );
    // 6 total - kafka(1) - spark(1).
    assert_eq!(service.machine().free_nodes(), 4);

    job.stop();
    producer_engine.stop();
    service.stop_pilot(&spark).unwrap();
    service.stop_pilot(&kafka).unwrap();
}

/// The §6.4 knee, closed-loop on the real plane: a burst pushes the
/// fleet past the topic's single partition, the controller repartitions
/// (and extends), and the post-repartition drain rate measurably
/// exceeds the one-task-per-partition capped rate.
#[test]
fn repartition_moves_the_one_task_per_partition_knee() {
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(8)));
    let (kafka, cluster) = service.start_kafka(KafkaDescription::new(1)).unwrap();
    let (spark, engine) = service
        .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
        .unwrap();
    cluster.create_topic("knee", 1).unwrap();

    // ~6 ms/message processor: one partition (one task per batch) caps
    // the drain rate at ~166 msg/s no matter how many executors exist.
    let processor = |_: &TaskContext, recs: &[Record]| {
        std::thread::sleep(Duration::from_millis(6) * recs.len() as u32);
        Ok(())
    };
    let mut jc = StreamingJobConfig::new("knee", Duration::from_millis(50));
    jc.group = "knee".into();
    // Small fetch slices keep the processed counter advancing smoothly
    // through long backlog-drain tasks, so rate measurements over fixed
    // windows aren't lumpy.
    jc.max_fetch_bytes = 16;
    let job = engine
        .start_job(cluster.clone(), jc, Arc::new(processor))
        .unwrap();

    // Continuous source outrunning the cap: bursts of 20 records every
    // 50 ms (~400 msg/s nominal), round-robin over the *live* partition
    // set, for ~6 s.
    let stop_producing = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let producer_thread = {
        let cluster = cluster.clone();
        let stop = stop_producing.clone();
        std::thread::spawn(move || {
            let mut rr = 0usize;
            let t0 = Instant::now();
            while !stop.load(std::sync::atomic::Ordering::Relaxed)
                && t0.elapsed() < Duration::from_secs(6)
            {
                let live = cluster.partition_count("knee").unwrap_or(1);
                for _ in 0..20 {
                    rr = (rr + 1) % live;
                    if cluster.produce("knee", rr, 7, &[vec![0u8]]).is_err() {
                        // Raced a repartition (stale epoch) or shutdown:
                        // re-read the live partition set next cycle.
                        break;
                    }
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        })
    };

    // Phase 1 — no autoscaler: measure the capped drain rate.
    std::thread::sleep(Duration::from_millis(500));
    let m0 = job.stats().processed.messages();
    std::thread::sleep(Duration::from_millis(1500));
    let m1 = job.stats().processed.messages();
    let capped_rate = (m1 - m0) as f64 / 1.5;
    assert!(capped_rate > 0.0, "job never processed anything");

    // Phase 2 — close the loop: the wrapped policy must repartition to
    // 4 (1 base + 3 extension task slots) and extend the pilot.
    let inner = ThresholdPolicy::new(25, 1)
        .with_sustain(2)
        .with_cooldown_secs(0.3)
        .with_step(3);
    let scaler = Autoscaler::spawn(
        service.clone(),
        spark.clone(),
        cluster.clone(),
        Some(job.stats().clone()),
        Box::new(PartitionElastic::new(inner, 1)),
        AutoscalerConfig::new("knee", "knee")
            .with_sample_interval(Duration::from_millis(50))
            .with_max_extension_nodes(3)
            .with_max_step(3)
            .with_window(Duration::from_millis(50)),
    );
    let timeline = scaler.timeline();
    assert!(
        wait_until(|| timeline.count(ScalingAction::Repartition) >= 1, 15.0),
        "controller never repartitioned; lag={:?}",
        cluster.group_lag("knee", "knee")
    );
    // The planner may right-size the extension below the policy's full
    // 3-node step once the service rate is calibrated (a smaller drain
    // benefit already covers the projected backlog) — and it shrinks
    // the partition ask with the fleet — so expect the cap to have
    // moved past 1 rather than pinning the full 4-partition fan-out.
    let parts = cluster.partition_count("knee").unwrap();
    assert!((2..=4).contains(&parts), "cap never moved: {parts} partitions");
    assert!(
        wait_until(|| engine.executor_count() >= 2, 10.0),
        "extension executors never attached"
    );

    // Phase 3 — post-repartition drain rate, while the source still
    // offers the same load.
    std::thread::sleep(Duration::from_millis(300));
    let m2 = job.stats().processed.messages();
    std::thread::sleep(Duration::from_millis(1500));
    let m3 = job.stats().processed.messages();
    let post_rate = (m3 - m2) as f64 / 1.5;
    assert!(
        post_rate > 1.4 * capped_rate,
        "knee did not move: capped {capped_rate:.0} msg/s vs post-repartition {post_rate:.0} msg/s"
    );

    // The burst fully drains once the source stops.
    stop_producing.store(true, std::sync::atomic::Ordering::Relaxed);
    producer_thread.join().unwrap();
    assert!(
        wait_until(|| cluster.group_lag("knee", "knee").unwrap() == 0, 60.0),
        "backlog never drained after the repartition"
    );

    // Timeline sanity: repartition precedes (or accompanies) the up,
    // and its recorded target matches the (possibly right-sized) ask.
    let events = timeline.events();
    let rp = events
        .iter()
        .position(|e| e.action == ScalingAction::Repartition)
        .unwrap();
    assert!(
        (2..=4).contains(&events[rp].partitions),
        "repartition target {} outside the right-sized range",
        events[rp].partitions
    );
    assert!(events.iter().any(|e| e.action == ScalingAction::Up));

    for p in scaler.stop() {
        service.stop_pilot(&p).unwrap();
    }
    job.stop();
    service.stop_pilot(&spark).unwrap();
    service.stop_pilot(&kafka).unwrap();
}

/// Cost-deferred scale-up: with a drain horizon shorter than the Spark
/// extension lead (~16 s modeled), the planner must refuse to extend —
/// the scale-up can never pay for itself before the horizon closes.
/// The deferral is a first-class timeline event; no pilot is extended.
#[test]
fn cost_deferred_scale_up_is_recorded_not_actuated() {
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(6)));
    let (kafka, cluster) = service.start_kafka(KafkaDescription::new(1)).unwrap();
    let (spark, engine) = service
        .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
        .unwrap();
    cluster.create_topic("defer", 2).unwrap();

    // A real consumer must run first: the cost gate only engages once
    // the probe has calibrated a per-node service rate from observed
    // consumption (an uncalibrated loop passes intents through).
    let processor = |_: &TaskContext, recs: &[Record]| {
        std::thread::sleep(Duration::from_millis(5) * recs.len() as u32);
        Ok(())
    };
    let mut jc = StreamingJobConfig::new("defer", Duration::from_millis(50));
    jc.group = "defer".into();
    let job = engine
        .start_job(cluster.clone(), jc, Arc::new(processor))
        .unwrap();

    let scaler = Autoscaler::spawn(
        service.clone(),
        spark.clone(),
        cluster.clone(),
        Some(job.stats().clone()),
        Box::new(
            ThresholdPolicy::new(15, 1)
                .with_sustain(2)
                .with_cooldown_secs(0.2)
                .with_step(3),
        ),
        AutoscalerConfig::new("defer", "defer")
            .with_sample_interval(Duration::from_millis(50))
            .with_max_extension_nodes(3)
            .with_max_step(3)
            .with_window(Duration::from_millis(50))
            // Spark extension lead is 16 modeled seconds; nothing can
            // pay for itself inside a 1 s horizon.
            .with_planner(PlannerConfig::default().with_drain_horizon_secs(1.0)),
    );

    // Priming trickle: enough to observe consumption (calibrating the
    // service-rate EWMA) without crossing the scale-up threshold.
    for i in 0..6u8 {
        cluster.produce("defer", (i % 2) as usize, 0, &[vec![i]]).unwrap();
        std::thread::sleep(Duration::from_millis(30));
    }
    std::thread::sleep(Duration::from_millis(400));

    // Backlog well past the threshold: the policy will demand nodes,
    // the planner must keep deferring.
    let batch: Vec<Vec<u8>> = (0..120u8).map(|i| vec![i]).collect();
    cluster.produce("defer", 0, 0, &batch).unwrap();
    cluster.produce("defer", 1, 0, &batch).unwrap();

    let timeline = scaler.timeline();
    assert!(
        wait_until(|| timeline.count(ScalingAction::Defer) >= 1, 20.0),
        "planner never recorded a deferral; lag={:?}",
        cluster.group_lag("defer", "defer")
    );
    // Give the loop room to (incorrectly) extend after the deferrals.
    std::thread::sleep(Duration::from_millis(500));
    assert_eq!(timeline.count(ScalingAction::Up), 0, "a deferred scale-up was actuated");
    assert_eq!(scaler.extension_count(), 0);
    assert_eq!(engine.executor_count(), 1, "base executor only");
    let defer = timeline
        .events()
        .into_iter()
        .find(|e| e.action == ScalingAction::Defer)
        .unwrap();
    assert!(
        defer.policy.contains("LeadBeyondHorizon"),
        "defer reason missing from event: {}",
        defer.policy
    );
    assert!(defer.lag >= 15, "deferral below the policy threshold: {}", defer.lag);

    let remaining = scaler.stop();
    assert!(remaining.is_empty());
    job.stop();
    service.stop_pilot(&spark).unwrap();
    service.stop_pilot(&kafka).unwrap();
    assert_eq!(service.machine().free_nodes(), 6);
}

/// Repartition-aware broker scale-up on the real plane: a repartition
/// whose new partition count oversubscribes the configured per-node I/O
/// budget must co-schedule a broker extension in the same plan — broker
/// first, then the repartition, then the processing extension.
#[test]
fn oversubscribing_repartition_coschedules_broker_extension() {
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(8)));
    let (kafka, cluster) = service.start_kafka(KafkaDescription::new(1)).unwrap();
    let (spark, engine) = service
        .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
        .unwrap();
    cluster.create_topic("co", 2).unwrap();
    assert_eq!(cluster.broker_nodes().len(), 1);

    let inner = ThresholdPolicy::new(10, 1)
        .with_sustain(2)
        .with_cooldown_secs(0.3)
        .with_step(3);
    let scaler = Autoscaler::spawn_with_broker(
        service.clone(),
        spark.clone(),
        Some(kafka.clone()),
        cluster.clone(),
        None,
        Box::new(PartitionElastic::new(inner, 1)),
        AutoscalerConfig::new("co", "g")
            .with_sample_interval(Duration::from_millis(50))
            .with_max_extension_nodes(3)
            .with_max_step(3)
            // Budget of 2 partitions per broker node: repartitioning to
            // 4 (1 base + 3 extension slots) needs a second broker.
            .with_planner(
                PlannerConfig::default()
                    .with_partitions_per_broker_node(2)
                    .with_max_broker_step(2),
            ),
    );

    // Standing lag, nobody consuming: the wrapped policy upgrades the
    // capped scale-up to a repartition, which oversubscribes the
    // 2-partition budget of the single broker.
    let batch: Vec<Vec<u8>> = (0..30u8).map(|i| vec![i]).collect();
    cluster.produce("co", 0, 0, &batch).unwrap();

    let timeline = scaler.timeline();
    assert!(
        wait_until(|| timeline.count(ScalingAction::BrokerUp) >= 1, 15.0),
        "no broker extension was co-scheduled"
    );
    assert!(
        wait_until(|| timeline.count(ScalingAction::Repartition) >= 1, 5.0),
        "no repartition followed the broker extension"
    );
    assert!(
        wait_until(|| scaler.extension_count() >= 1, 5.0),
        "no processing extension landed"
    );
    assert_eq!(cluster.broker_nodes().len(), 2, "broker tier extended");
    assert_eq!(cluster.partition_count("co").unwrap(), 4);
    assert_eq!(scaler.broker_extension_count(), 1);
    assert!(
        wait_until(|| engine.executor_count() == 4, 10.0),
        "extension executors never attached"
    );

    // Step order within the plan: broker first (so the new partitions
    // land on an unsaturated tier), then the repartition, then the
    // processing extension.
    let events = timeline.events();
    let broker_up = events.iter().position(|e| e.action == ScalingAction::BrokerUp).unwrap();
    let rp = events.iter().position(|e| e.action == ScalingAction::Repartition).unwrap();
    let up = events.iter().position(|e| e.action == ScalingAction::Up).unwrap();
    assert!(broker_up < rp && rp < up, "plan steps out of order: {events:?}");
    assert_eq!(events[rp].partitions, 4);
    // The broker step carries the Kafka extension cost model (one wave
    // of 1 node + rebalance settle = 8 + 15), the processing step
    // Spark's (two waves of 3 nodes + settle = 12 + 10).
    assert_eq!(events[broker_up].cost_secs, 23.0);
    assert_eq!(events[up].cost_secs, 22.0);

    // Drain the backlog: the processing extensions are released, but
    // the co-scheduled broker node must *stay* — the 4 partitions it
    // was bought for persist, and the base broker alone (budget 2)
    // cannot serve them.
    for part in 0..4 {
        let end = cluster.end_offset("co", part).unwrap();
        cluster.commit("g", "co", part, end);
    }
    assert!(
        wait_until(
            || timeline.count(ScalingAction::Down) >= 1 && scaler.extension_count() == 0,
            30.0
        ),
        "processing never scaled back down"
    );
    std::thread::sleep(Duration::from_millis(300));
    assert_eq!(scaler.broker_extension_count(), 1, "broker released despite partitions");
    assert_eq!(cluster.broker_nodes().len(), 2);
    assert_eq!(timeline.count(ScalingAction::BrokerDown), 0);

    for p in scaler.stop() {
        service.stop_pilot(&p).unwrap();
    }
    assert_eq!(cluster.broker_nodes().len(), 1, "broker shrank back");
    service.stop_pilot(&spark).unwrap();
    service.stop_pilot(&kafka).unwrap();
    assert_eq!(service.machine().free_nodes(), 8);
}

#[test]
fn autoscaler_respects_extension_ceiling_and_machine_capacity() {
    // Machine with exactly one spare node: the policy may ask for 4 but
    // only one extension can materialize, and the loop must not error.
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(3)));
    let (kafka, cluster) = service.start_kafka(KafkaDescription::new(1)).unwrap();
    let (spark, engine) = service
        .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
        .unwrap();
    cluster.create_topic("t", 2).unwrap();

    let scaler = Autoscaler::spawn(
        service.clone(),
        spark.clone(),
        cluster.clone(),
        None,
        Box::new(ThresholdPolicy::new(5, 1).with_sustain(1).with_cooldown_secs(0.1).with_step(4)),
        AutoscalerConfig::new("t", "g")
            .with_sample_interval(Duration::from_millis(30))
            .with_max_extension_nodes(4)
            .with_max_step(4),
    );
    // Standing lag, nobody consuming.
    let batch: Vec<Vec<u8>> = (0..30u8).map(|i| vec![i]).collect();
    cluster.produce("t", 0, 0, &batch).unwrap();

    assert!(
        wait_until(|| scaler.extension_count() >= 1, 10.0),
        "no extension appeared"
    );
    // Give the loop time to (incorrectly) over-allocate; it can't: the
    // machine is full.
    std::thread::sleep(Duration::from_millis(300));
    assert_eq!(engine.executor_count(), 2, "1 base + the single spare node");
    assert_eq!(service.machine().free_nodes(), 0);

    for p in scaler.stop() {
        service.stop_pilot(&p).unwrap();
    }
    service.stop_pilot(&spark).unwrap();
    service.stop_pilot(&kafka).unwrap();
    assert_eq!(service.machine().free_nodes(), 3);
}
