//! Property-based invariants over online topic repartitioning.
//!
//! Repartitioning rewrites live offsets and assignments, so its safety
//! story is this suite: across random interleavings of keyed produces,
//! grows/shrinks, consumer-group membership churn and polls, we assert
//!
//! * **(a) exactly-once** — every produced record is consumed exactly
//!   once (no loss, no duplication) after the final drain;
//! * **(b) per-key order** — each key's records are observed in produce
//!   order, across every epoch transition (keys *move* partitions when
//!   the topic resizes; the drain-before-serve fence must keep their
//!   order);
//! * **(c) non-negative lag** — `group_progress` never reports a
//!   committed offset past an end offset, at every observation point.
//!
//! The chaos variants layer a replicated broker tier on top: a random
//! broker kill mid-interleaving (factor-2 failover), and — with the
//! async-replication lag model in play — random follower-lag injection
//! driving ISR shrink/expand churn.  Under [`AckMode::Quorum`] the
//! quorum gate may *reject* produces but must never lose an acked
//! record to the kill; under [`AckMode::Leader`] an unclean election
//! must lose *exactly* the follower gap the public lag gauges reported
//! the instant before the kill.  The rack variant scales the blast
//! radius up: an entire failure domain dies at once mid-produce, every
//! victim later re-joins, and quorum durability must hold across the
//! whole bounce with zero divergence to truncate.
//!
//! Like `proptest_invariants.rs`, this is a seeded-random harness (the
//! offline dependency set has no `proptest`): failures print the seed
//! for replay, and `PROPTEST_CASES` scales the case count (the CI
//! `proptest` job runs these suites deeper than the default
//! `cargo test` pass).

use std::sync::Arc;
use std::time::Duration;

use pilot_streaming::broker::{
    AckMode, BrokerCluster, Consumer, ConsumerConfig, PartitionRecord, Partitioner, Producer,
    ProducerConfig, ReplicationConfig,
};
use pilot_streaming::cluster::Machine;
use pilot_streaming::metrics::{ScalingAction, ScalingTimeline};
use pilot_streaming::util::Rng;

/// Case count: `PROPTEST_CASES` env override, else the suite default.
fn cases(default: usize) -> usize {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Run `f` over seeded cases; panic messages carry the seed for replay.
fn check<F: Fn(&mut Rng)>(name: &str, default_cases: usize, f: F) {
    for case in 0..cases(default_cases) {
        let seed = 0xD00F ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {e:?}");
        }
    }
}

fn encode(key: usize, seq: u32) -> Vec<u8> {
    vec![
        key as u8,
        (seq >> 24) as u8,
        (seq >> 16) as u8,
        (seq >> 8) as u8,
        seq as u8,
    ]
}

fn decode(value: &[u8]) -> (usize, u32) {
    (
        value[0] as usize,
        u32::from_be_bytes([value[1], value[2], value[3], value[4]]),
    )
}

fn consumer_config() -> ConsumerConfig {
    ConsumerConfig {
        fetch_timeout: Duration::from_millis(1),
        ..Default::default()
    }
}

/// Invariant (b): each key's records arrive in dense produce order.
fn observe(recs: Vec<PartitionRecord>, consumed_seq: &mut [u32], consumed_total: &mut usize) {
    for r in recs {
        let (k, seq) = decode(&r.record.value);
        assert_eq!(
            seq, consumed_seq[k],
            "key {k}: expected seq {} next, saw {seq} (reorder/dup/loss)",
            consumed_seq[k]
        );
        consumed_seq[k] += 1;
        *consumed_total += 1;
    }
}

/// The flagship interleaving property: produce / repartition / churn /
/// consume in random order, then drain and check (a), (b), (c).
#[test]
fn prop_repartition_exactly_once_ordered_nonnegative() {
    check("repartition-interleavings", 25, |rng| {
        let n_keys = 2 + rng.below(6);
        let machine = Machine::unthrottled(4);
        let cluster = BrokerCluster::new(machine, vec![0]);
        cluster.create_topic("t", 1 + rng.below(4)).unwrap();

        // Half the cases flush every record; the other half batch a few
        // records per partition, so resizes catch in-flight batches and
        // the producer's key-aware re-route is exercised too.
        let batch_bytes = if rng.below(2) == 0 { 1 } else { 24 };
        let mut producer = Producer::new(
            cluster.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes,
                partitioner: Partitioner::Keyed,
                ..Default::default()
            },
        )
        .unwrap();
        let mut consumers =
            vec![Consumer::join(cluster.clone(), "t", "g", 2, consumer_config()).unwrap()];

        // Per-key produced count and next-expected consumed seq.
        let mut produced_seq = vec![0u32; n_keys];
        let mut consumed_seq = vec![0u32; n_keys];
        let mut produced_total = 0usize;
        let mut consumed_total = 0usize;

        let steps = 10 + rng.below(25);
        for _ in 0..steps {
            match rng.below(10) {
                // Produce a keyed burst (sometimes flushing pending
                // batches so they land before the next resize).
                0..=4 => {
                    for _ in 0..1 + rng.below(8) {
                        let k = rng.below(n_keys);
                        let seq = produced_seq[k];
                        produced_seq[k] += 1;
                        producer.send(Some(&[k as u8]), encode(k, seq)).unwrap();
                        produced_total += 1;
                    }
                    if rng.below(2) == 0 {
                        producer.flush().unwrap();
                    }
                }
                // Resize the topic (grow or shrink) mid-stream.
                5 | 6 => {
                    cluster.repartition_topic("t", 1 + rng.below(8)).unwrap();
                }
                // Membership churn: join or leave (never below 1).
                7 => {
                    if consumers.len() > 1 && rng.below(2) == 0 {
                        let idx = rng.below(consumers.len());
                        consumers.remove(idx); // drop commits + leaves
                    } else if consumers.len() < 3 {
                        consumers.push(
                            Consumer::join(cluster.clone(), "t", "g", 3, consumer_config())
                                .unwrap(),
                        );
                    }
                }
                // Poll a random consumer a few times.
                _ => {
                    for _ in 0..1 + rng.below(4) {
                        let idx = rng.below(consumers.len());
                        let recs = consumers[idx].poll().unwrap();
                        observe(recs, &mut consumed_seq, &mut consumed_total);
                    }
                }
            }
            // Invariant (c) after every step: committed never exceeds
            // the end offset on any partition, live or retired.
            for (end, committed) in cluster.group_progress("g", "t").unwrap() {
                assert!(
                    committed <= end,
                    "negative lag: committed {committed} > end {end}"
                );
            }
        }

        // Final drain: poll everyone until all records are accounted
        // for (bounded, so invariant violations fail fast rather than
        // hang).
        producer.flush().unwrap();
        let mut idle_rounds = 0;
        while consumed_total < produced_total && idle_rounds < 300 {
            let mut progressed = false;
            for c in consumers.iter_mut() {
                let recs = c.poll().unwrap();
                if !recs.is_empty() {
                    progressed = true;
                }
                observe(recs, &mut consumed_seq, &mut consumed_total);
            }
            if progressed {
                idle_rounds = 0;
            } else {
                idle_rounds += 1;
            }
        }

        // Invariant (a): exactly once, in aggregate and per key.
        assert_eq!(
            consumed_total, produced_total,
            "exactly-once violated: {consumed_total} consumed of {produced_total} produced"
        );
        assert_eq!(consumed_seq, produced_seq, "per-key completeness");
        // And the group's lag is fully drained.
        assert_eq!(cluster.group_lag("g", "t").unwrap(), 0);
    });
}

/// The chaos variant: same interleaving over a *replicated* topic on a
/// three-node broker tier, with one broker killed at a random point —
/// possibly between a repartition and the drains it fences.  Factor-2
/// replication mirrors every append synchronously, so every acked
/// produce must survive the failover: exactly-once (a), per-key order
/// (b) and non-negative lag (c) all hold across the node death, and
/// committed group offsets are never rolled back by it.
#[test]
fn prop_failover_mid_repartition_keeps_acked_records_exactly_once() {
    check("failover-mid-repartition", 15, |rng| {
        let n_keys = 2 + rng.below(6);
        let machine = Machine::unthrottled(6);
        let cluster = BrokerCluster::new(machine, vec![0, 1, 2]);
        cluster
            .create_topic_replicated("t", 1 + rng.below(4), ReplicationConfig::new(2))
            .unwrap();

        let batch_bytes = if rng.below(2) == 0 { 1 } else { 24 };
        let mut producer = Producer::new(
            cluster.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes,
                partitioner: Partitioner::Keyed,
                ..Default::default()
            },
        )
        .unwrap();
        let mut consumers =
            vec![Consumer::join(cluster.clone(), "t", "g", 2, consumer_config()).unwrap()];

        let mut produced_seq = vec![0u32; n_keys];
        let mut consumed_seq = vec![0u32; n_keys];
        let mut produced_total = 0usize;
        let mut consumed_total = 0usize;

        // Exactly one node death per case, at a random step (killing a
        // second of three nodes would leave factor 2 > fleet and is the
        // spec-level rejection's job, not this property's).
        let mut killed = false;
        let steps = 10 + rng.below(25);
        for step in 0..steps {
            let kill_at = !killed && (rng.below(steps - step) == 0 || step == steps - 1);
            if kill_at {
                let nodes = cluster.broker_nodes();
                let victim = nodes[rng.below(nodes.len())];
                let report = cluster.kill_broker(victim).unwrap();
                // Factor 2 over 3 nodes: every partition the victim led
                // had a live follower to promote; none were stranded.
                assert_eq!(report.unreplicated, 0, "factor-2 partition had no follower");
                killed = true;
                continue;
            }
            match rng.below(10) {
                0..=4 => {
                    for _ in 0..1 + rng.below(8) {
                        let k = rng.below(n_keys);
                        let seq = produced_seq[k];
                        produced_seq[k] += 1;
                        producer.send(Some(&[k as u8]), encode(k, seq)).unwrap();
                        produced_total += 1;
                    }
                    if rng.below(2) == 0 {
                        producer.flush().unwrap();
                    }
                }
                // Resize mid-stream; fresh partitions inherit factor-2
                // replica sets over the surviving membership.
                5 | 6 => {
                    cluster.repartition_topic("t", 1 + rng.below(8)).unwrap();
                }
                7 => {
                    if consumers.len() > 1 && rng.below(2) == 0 {
                        let idx = rng.below(consumers.len());
                        consumers.remove(idx);
                    } else if consumers.len() < 3 {
                        consumers.push(
                            Consumer::join(cluster.clone(), "t", "g", 3, consumer_config())
                                .unwrap(),
                        );
                    }
                }
                _ => {
                    for _ in 0..1 + rng.below(4) {
                        let idx = rng.below(consumers.len());
                        let recs = consumers[idx].poll().unwrap();
                        observe(recs, &mut consumed_seq, &mut consumed_total);
                    }
                }
            }
            // Invariant (c) holds through the failover too: committed
            // offsets survive the node death and never pass an end.
            for (end, committed) in cluster.group_progress("g", "t").unwrap() {
                assert!(
                    committed <= end,
                    "negative lag: committed {committed} > end {end}"
                );
            }
        }
        assert!(killed, "the schedule above always kills one broker");
        assert_eq!(cluster.broker_nodes().len(), 2);

        producer.flush().unwrap();
        let mut idle_rounds = 0;
        while consumed_total < produced_total && idle_rounds < 300 {
            let mut progressed = false;
            for c in consumers.iter_mut() {
                let recs = c.poll().unwrap();
                if !recs.is_empty() {
                    progressed = true;
                }
                observe(recs, &mut consumed_seq, &mut consumed_total);
            }
            if progressed {
                idle_rounds = 0;
            } else {
                idle_rounds += 1;
            }
        }

        // Invariant (a) across the failover: every acked record is
        // consumed exactly once — nothing the dead broker led was lost,
        // nothing was replayed.
        assert_eq!(
            consumed_total, produced_total,
            "exactly-once violated across failover: {consumed_total} of {produced_total}"
        );
        assert_eq!(consumed_seq, produced_seq, "per-key completeness across failover");
        assert_eq!(cluster.group_lag("g", "t").unwrap(), 0);
    });
}

/// ISR-churn chaos under [`AckMode::Quorum`]: random follower-lag
/// injection interleaves with produces, resizes, consumer churn and
/// one broker kill over a factor-2 / `min_insync` 2 topic.  The quorum
/// gate may *reject* produces while a slow follower is out of the ISR
/// — rejection is the contract — but it must never lose a record it
/// acked: at kill time every follower watermark equals its leader's
/// end offset (zero gap, on every partition live or retired), the
/// failover reports zero lost records, and the drain observes every
/// acked record exactly once, in per-key order.
#[test]
fn prop_isr_churn_quorum_rejects_rather_than_lose() {
    const LAGS: [u64; 5] = [0, 1, 2, 5, 50];
    check("isr-churn-quorum-durability", 12, |rng| {
        let n_keys = 2 + rng.below(6);
        let machine = Machine::unthrottled(6);
        let cluster = BrokerCluster::new(machine, vec![0, 1, 2]);
        cluster
            .create_topic_replicated(
                "t",
                1 + rng.below(4),
                ReplicationConfig::new(2)
                    .with_ack_mode(AckMode::Quorum)
                    .with_min_insync(2)
                    .with_replica_lag_max(2),
            )
            .unwrap();

        // batch_bytes 1: every send flushes exactly its own record, so
        // a quorum rejection drops that record alone — its per-key seq
        // was never acked and is reused by the next send for that key.
        let mut producer = Producer::new(
            cluster.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes: 1,
                partitioner: Partitioner::Keyed,
                ..Default::default()
            },
        )
        .unwrap();
        let mut consumers =
            vec![Consumer::join(cluster.clone(), "t", "g", 2, consumer_config()).unwrap()];

        let mut produced_seq = vec![0u32; n_keys];
        let mut consumed_seq = vec![0u32; n_keys];
        let mut produced_total = 0usize;
        let mut consumed_total = 0usize;
        let mut rejected_total = 0usize;

        let mut killed = false;
        let steps = 10 + rng.below(25);
        for step in 0..steps {
            let kill_at = !killed && (rng.below(steps - step) == 0 || step == steps - 1);
            if kill_at {
                // The quorum durability invariant, at its sharpest
                // right before the kill: every acked record is fully
                // applied by every follower, so no partition — live or
                // retired — has a watermark gap on any node.  (A gap
                // here would become `lost_records` below.)
                let nodes = cluster.broker_nodes();
                for p in 0..cluster.total_partitions("t").unwrap() {
                    for &n in &nodes {
                        assert_eq!(
                            cluster.follower_gap("t", p, n).unwrap(),
                            0,
                            "quorum left partition {p} partially applied on node {n}"
                        );
                    }
                }
                let victim = nodes[rng.below(nodes.len())];
                let report = cluster.kill_broker(victim).unwrap();
                assert_eq!(report.unreplicated, 0, "factor-2 partition had no follower");
                assert_eq!(
                    report.lost_records, 0,
                    "quorum acked a record a promoted follower never applied"
                );
                killed = true;
                continue;
            }
            match rng.below(12) {
                // Produce a keyed burst.  Under Quorum a send is either
                // acked (count it) or rejected by the quorum gate while
                // the ISR is short (drop it; never a silent loss).
                0..=4 => {
                    for _ in 0..1 + rng.below(8) {
                        let k = rng.below(n_keys);
                        match producer.send(Some(&[k as u8]), encode(k, produced_seq[k])) {
                            Ok(_) => {
                                produced_seq[k] += 1;
                                produced_total += 1;
                            }
                            Err(e) => {
                                assert!(
                                    e.to_string().contains("in-sync"),
                                    "only the quorum gate may reject a produce: {e}"
                                );
                                rejected_total += 1;
                            }
                        }
                    }
                }
                5 | 6 => {
                    cluster.repartition_topic("t", 1 + rng.below(8)).unwrap();
                }
                7 => {
                    if consumers.len() > 1 && rng.below(2) == 0 {
                        let idx = rng.below(consumers.len());
                        consumers.remove(idx);
                    } else if consumers.len() < 3 {
                        consumers.push(
                            Consumer::join(cluster.clone(), "t", "g", 3, consumer_config())
                                .unwrap(),
                        );
                    }
                }
                // ISR churn: re-model a random broker's NIC/disk as
                // slower or healthy again; a heartbeat sometimes lets
                // followers catch up (and re-enter the ISR) between
                // produces.
                8 | 9 => {
                    let nodes = cluster.broker_nodes();
                    let node = nodes[rng.below(nodes.len())];
                    cluster
                        .inject_follower_lag("t", node, LAGS[rng.below(LAGS.len())])
                        .unwrap();
                    if rng.below(2) == 0 {
                        cluster.replication_heartbeat("t").unwrap();
                    }
                }
                _ => {
                    for _ in 0..1 + rng.below(4) {
                        let idx = rng.below(consumers.len());
                        let recs = consumers[idx].poll().unwrap();
                        observe(recs, &mut consumed_seq, &mut consumed_total);
                    }
                }
            }
            for (end, committed) in cluster.group_progress("g", "t").unwrap() {
                assert!(
                    committed <= end,
                    "negative lag: committed {committed} > end {end}"
                );
            }
        }
        assert!(killed, "the schedule above always kills one broker");

        let mut idle_rounds = 0;
        while consumed_total < produced_total && idle_rounds < 300 {
            let mut progressed = false;
            for c in consumers.iter_mut() {
                let recs = c.poll().unwrap();
                if !recs.is_empty() {
                    progressed = true;
                }
                observe(recs, &mut consumed_seq, &mut consumed_total);
            }
            if progressed {
                idle_rounds = 0;
            } else {
                idle_rounds += 1;
            }
        }

        assert_eq!(
            consumed_total, produced_total,
            "exactly-once violated across ISR churn + failover: {consumed_total} consumed \
             of {produced_total} acked ({rejected_total} rejected by the quorum gate)"
        );
        assert_eq!(consumed_seq, produced_seq, "per-key completeness across ISR churn");
        assert_eq!(cluster.group_lag("g", "t").unwrap(), 0);
    });
}

/// Whole-rack chaos under [`AckMode::Quorum`]: four brokers striped
/// across two failure domains, factor-2 rack-anti-affine placement,
/// and one entire rack killed atomically at a random point in the
/// interleaving — so *every* partition loses a replica in the same
/// instant.  While the tier is degraded the quorum gate may only
/// *reject* produces (ISR 1 < `min_insync` 2 on every pre-kill
/// partition); it must never lose an acked record.  Every victim then
/// re-joins: under quorum nothing diverged, so each
/// [`rejoin_broker`](BrokerCluster::rejoin_broker) truncates exactly
/// zero records, and once the returners catch up the quorum path
/// accepts produces again.  Exactly-once and per-key order hold across
/// the full rack bounce.
#[test]
fn prop_rack_kill_quorum_rejects_rather_than_lose_and_rejoin_heals() {
    const LAGS: [u64; 4] = [0, 1, 2, 5];
    check("rack-kill-quorum-durability", 10, |rng| {
        let n_keys = 2 + rng.below(6);
        let machine = Machine::unthrottled(8);
        // Nodes at membership positions {0,2} form rack 0, {1,3} rack 1.
        let cluster = BrokerCluster::with_racks(machine, vec![0, 1, 2, 3], 2);
        cluster
            .create_topic_replicated(
                "t",
                1 + rng.below(4),
                ReplicationConfig::new(2)
                    .with_ack_mode(AckMode::Quorum)
                    .with_min_insync(2)
                    .with_replica_lag_max(2),
            )
            .unwrap();
        // Two domains cover factor 2: placement never needs a fallback.
        assert_eq!(cluster.rack_constraint_violations(), 0);

        let mut producer = Producer::new(
            cluster.clone(),
            "t",
            4,
            ProducerConfig {
                batch_bytes: 1,
                partitioner: Partitioner::Keyed,
                ..Default::default()
            },
        )
        .unwrap();
        let mut consumers =
            vec![Consumer::join(cluster.clone(), "t", "g", 5, consumer_config()).unwrap()];

        let mut produced_seq = vec![0u32; n_keys];
        let mut consumed_seq = vec![0u32; n_keys];
        let mut produced_total = 0usize;
        let mut consumed_total = 0usize;
        let mut rejected_total = 0usize;

        // One rack death per case at a random step; its victims re-join
        // (still mid-interleaving when the schedule allows it).
        let mut victims: Vec<pilot_streaming::cluster::NodeId> = Vec::new();
        let mut rejoined = false;
        let steps = 12 + rng.below(25);
        for step in 0..steps {
            if victims.is_empty() && (rng.below(steps - step) == 0 || step + 2 >= steps) {
                // Quorum's durability invariant at its sharpest, right
                // before the whole domain dies: no acked record is
                // missing from any replica, so killing every broker of
                // a rack at once promotes only fully-caught-up
                // survivors and loses nothing.
                let rack = rng.below(2);
                let reports = cluster.kill_rack(rack).unwrap();
                assert_eq!(reports.len(), 2, "each domain holds two brokers");
                for r in &reports {
                    assert_eq!(r.unreplicated, 0, "anti-affine factor-2 set had no survivor");
                    assert_eq!(
                        r.lost_records, 0,
                        "quorum acked a record the surviving rack never applied"
                    );
                }
                victims = reports.iter().map(|r| r.killed).collect();
                assert_eq!(cluster.broker_nodes().len(), 2);
                continue;
            }
            if !victims.is_empty() && !rejoined && (rng.below(4) == 0 || step + 1 >= steps) {
                for &v in &victims {
                    let report = cluster.rejoin_broker(v).unwrap();
                    assert_eq!(
                        report.truncated_records, 0,
                        "nothing diverged under quorum, yet node {v} truncated its tail"
                    );
                }
                assert_eq!(cluster.broker_nodes().len(), 4);
                rejoined = true;
                continue;
            }
            match rng.below(12) {
                // Keyed burst: acked or rejected by the quorum gate,
                // never silently dropped.  The degraded window rejects
                // everything on pre-kill partitions (sole survivor < 2
                // in-sync replicas) — that *is* the contract.
                0..=4 => {
                    for _ in 0..1 + rng.below(8) {
                        let k = rng.below(n_keys);
                        match producer.send(Some(&[k as u8]), encode(k, produced_seq[k])) {
                            Ok(_) => {
                                produced_seq[k] += 1;
                                produced_total += 1;
                            }
                            Err(e) => {
                                assert!(
                                    e.to_string().contains("in-sync"),
                                    "only the quorum gate may reject a produce: {e}"
                                );
                                rejected_total += 1;
                            }
                        }
                    }
                }
                5 | 6 => {
                    cluster.repartition_topic("t", 1 + rng.below(8)).unwrap();
                }
                7 => {
                    if consumers.len() > 1 && rng.below(2) == 0 {
                        let idx = rng.below(consumers.len());
                        consumers.remove(idx);
                    } else if consumers.len() < 3 {
                        consumers.push(
                            Consumer::join(cluster.clone(), "t", "g", 5, consumer_config())
                                .unwrap(),
                        );
                    }
                }
                8 | 9 => {
                    let nodes = cluster.broker_nodes();
                    let node = nodes[rng.below(nodes.len())];
                    cluster
                        .inject_follower_lag("t", node, LAGS[rng.below(LAGS.len())])
                        .unwrap();
                    if rng.below(2) == 0 {
                        cluster.replication_heartbeat("t").unwrap();
                    }
                }
                _ => {
                    for _ in 0..1 + rng.below(4) {
                        let idx = rng.below(consumers.len());
                        let recs = consumers[idx].poll().unwrap();
                        observe(recs, &mut consumed_seq, &mut consumed_total);
                    }
                }
            }
            for (end, committed) in cluster.group_progress("g", "t").unwrap() {
                assert!(
                    committed <= end,
                    "negative lag: committed {committed} > end {end}"
                );
            }
        }
        assert!(!victims.is_empty(), "the schedule above always kills one rack");
        assert!(rejoined, "every victim re-joined before the drain");

        // Heal the tier: clear injected lag, let returners catch up and
        // re-enter their ISRs, then the quorum path must accept again.
        for &n in &cluster.broker_nodes() {
            cluster.inject_follower_lag("t", n, 0).unwrap();
        }
        // Twice: one pass applies outstanding appends, the next sees
        // every gap at zero and expands the ISRs.
        cluster.replication_heartbeat("t").unwrap();
        cluster.replication_heartbeat("t").unwrap();
        for _ in 0..3 {
            let k = rng.below(n_keys);
            producer
                .send(Some(&[k as u8]), encode(k, produced_seq[k]))
                .expect("quorum must accept once the bounced rack caught back up");
            produced_seq[k] += 1;
            produced_total += 1;
        }

        let mut idle_rounds = 0;
        while consumed_total < produced_total && idle_rounds < 300 {
            let mut progressed = false;
            for c in consumers.iter_mut() {
                let recs = c.poll().unwrap();
                if !recs.is_empty() {
                    progressed = true;
                }
                observe(recs, &mut consumed_seq, &mut consumed_total);
            }
            if progressed {
                idle_rounds = 0;
            } else {
                idle_rounds += 1;
            }
        }

        assert_eq!(
            consumed_total, produced_total,
            "exactly-once violated across the rack bounce: {consumed_total} consumed \
             of {produced_total} acked ({rejected_total} rejected by the quorum gate)"
        );
        assert_eq!(consumed_seq, produced_seq, "per-key completeness across the rack bounce");
        assert_eq!(cluster.group_lag("g", "t").unwrap(), 0);
    });
}

/// Unclean-election accounting under [`AckMode::Leader`]: followers
/// trail by their injected lag, and killing a leader promotes the
/// (possibly out-of-ISR) follower anyway — losing exactly the records
/// above its watermark.  The kill report, the attached
/// [`ScalingTimeline`], and the queued failover event must all agree
/// with a prediction computed from the *public* lag gauges
/// (`leader_node` + `follower_gap` + `in_sync_replicas`) the instant
/// before the kill.  The loss is an accounting construct — the shared
/// slabs keep every byte readable in-process — so exactly-once still
/// holds for the drain; the timeline is where the durability debt
/// surfaces.
#[test]
fn prop_unclean_election_loses_exactly_the_reported_gap() {
    const LAGS: [u64; 4] = [0, 1, 5, 50];
    check("unclean-election-accounting", 12, |rng| {
        let n_keys = 2 + rng.below(6);
        let machine = Machine::unthrottled(6);
        let cluster = BrokerCluster::new(machine, vec![0, 1, 2]);
        cluster
            .create_topic_replicated(
                "t",
                1 + rng.below(4),
                ReplicationConfig::new(2).with_replica_lag_max(2),
            )
            .unwrap();
        let timeline = Arc::new(ScalingTimeline::new());
        cluster.add_scaling_timeline(timeline.clone());

        let mut producer = Producer::new(
            cluster.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes: 1,
                partitioner: Partitioner::Keyed,
                ..Default::default()
            },
        )
        .unwrap();
        let mut consumers =
            vec![Consumer::join(cluster.clone(), "t", "g", 2, consumer_config()).unwrap()];

        let mut produced_seq = vec![0u32; n_keys];
        let mut consumed_seq = vec![0u32; n_keys];
        let mut produced_total = 0usize;
        let mut consumed_total = 0usize;

        let mut killed = false;
        let steps = 10 + rng.below(25);
        for step in 0..steps {
            let kill_at = !killed && (rng.below(steps - step) == 0 || step == steps - 1);
            if kill_at {
                let alive = cluster.broker_nodes();
                let victim = alive[rng.below(alive.len())];
                // Predict the loss from the public gauges: for every
                // partition the victim leads (retired suffixes
                // included — the failover inspects them too), the sole
                // factor-2 follower's gap is what an unclean promotion
                // abandons, and that promotion is unclean exactly when
                // the follower is out of the ISR.
                let total = cluster.total_partitions("t").unwrap();
                let mut expected_lost = 0u64;
                let mut expected_unclean = 0usize;
                for p in 0..total {
                    if cluster.leader_node("t", p).unwrap() != victim {
                        continue;
                    }
                    for &n in &alive {
                        if n != victim {
                            expected_lost += cluster.follower_gap("t", p, n).unwrap();
                        }
                    }
                    if cluster.in_sync_replicas("t", p).unwrap().len() < 2 {
                        expected_unclean += 1;
                    }
                }
                let report = cluster.kill_broker(victim).unwrap();
                assert_eq!(report.unreplicated, 0, "factor-2 partition had no follower");
                assert_eq!(
                    report.lost_records, expected_lost,
                    "failover must lose exactly the follower gaps the gauges reported"
                );
                assert_eq!(
                    report.unclean_elections, expected_unclean,
                    "unclean elections are exactly the out-of-ISR promotions"
                );
                // The same number lands on the timeline and on the
                // queued event the autoscale loop drains.
                let events = timeline.events();
                let fail = events
                    .iter()
                    .rev()
                    .find(|e| matches!(e.action, ScalingAction::Failover))
                    .expect("kill_broker records a Failover event");
                assert_eq!(fail.lost_records, expected_lost);
                let queued = cluster.take_failover_events();
                assert_eq!(queued.len(), 1);
                assert_eq!(queued[0].killed, victim);
                assert_eq!(queued[0].lost_records, expected_lost);
                killed = true;
                continue;
            }
            match rng.below(12) {
                // Leader acks never consult the ISR: sends always land.
                0..=4 => {
                    for _ in 0..1 + rng.below(8) {
                        let k = rng.below(n_keys);
                        let seq = produced_seq[k];
                        produced_seq[k] += 1;
                        producer.send(Some(&[k as u8]), encode(k, seq)).unwrap();
                        produced_total += 1;
                    }
                }
                5 | 6 => {
                    cluster.repartition_topic("t", 1 + rng.below(8)).unwrap();
                }
                7 => {
                    if consumers.len() > 1 && rng.below(2) == 0 {
                        let idx = rng.below(consumers.len());
                        consumers.remove(idx);
                    } else if consumers.len() < 3 {
                        consumers.push(
                            Consumer::join(cluster.clone(), "t", "g", 3, consumer_config())
                                .unwrap(),
                        );
                    }
                }
                8 | 9 => {
                    let nodes = cluster.broker_nodes();
                    let node = nodes[rng.below(nodes.len())];
                    cluster
                        .inject_follower_lag("t", node, LAGS[rng.below(LAGS.len())])
                        .unwrap();
                    if rng.below(2) == 0 {
                        cluster.replication_heartbeat("t").unwrap();
                    }
                }
                _ => {
                    for _ in 0..1 + rng.below(4) {
                        let idx = rng.below(consumers.len());
                        let recs = consumers[idx].poll().unwrap();
                        observe(recs, &mut consumed_seq, &mut consumed_total);
                    }
                }
            }
            for (end, committed) in cluster.group_progress("g", "t").unwrap() {
                assert!(
                    committed <= end,
                    "negative lag: committed {committed} > end {end}"
                );
            }
        }
        assert!(killed, "the schedule above always kills one broker");

        producer.flush().unwrap();
        let mut idle_rounds = 0;
        while consumed_total < produced_total && idle_rounds < 300 {
            let mut progressed = false;
            for c in consumers.iter_mut() {
                let recs = c.poll().unwrap();
                if !recs.is_empty() {
                    progressed = true;
                }
                observe(recs, &mut consumed_seq, &mut consumed_total);
            }
            if progressed {
                idle_rounds = 0;
            } else {
                idle_rounds += 1;
            }
        }

        // The in-process model keeps "lost" records readable (the
        // accounting, not the bytes, is what an unclean election
        // burns), so exactly-once still holds end to end.
        assert_eq!(
            consumed_total, produced_total,
            "exactly-once violated: {consumed_total} of {produced_total}"
        );
        assert_eq!(consumed_seq, produced_seq, "per-key completeness");
        assert_eq!(cluster.group_lag("g", "t").unwrap(), 0);
    });
}

/// Committed progress survives any sequence of resizes untouched:
/// partition ids are stable, so offsets committed before a repartition
/// read back identically after it.
#[test]
fn prop_repartition_preserves_committed_offsets() {
    check("repartition-offset-migration", 100, |rng| {
        let cluster = BrokerCluster::new(Machine::unthrottled(2), vec![0]);
        let initial = 1 + rng.below(6);
        cluster.create_topic("t", initial).unwrap();
        cluster.group_join("g", "t");

        let mut committed: Vec<u64> = vec![0; initial];
        for _ in 0..1 + rng.below(12) {
            match rng.below(3) {
                // Produce + commit some progress on a live partition.
                0 | 1 => {
                    let live = cluster.partition_count("t").unwrap();
                    let p = rng.below(live);
                    let n = 1 + rng.below(5) as u64;
                    for _ in 0..n {
                        cluster.produce("t", p, 0, &[vec![0u8]]).unwrap();
                    }
                    let end = cluster.end_offset("t", p).unwrap();
                    cluster.commit("g", "t", p, end);
                    if p >= committed.len() {
                        committed.resize(p + 1, 0);
                    }
                    committed[p] = end;
                }
                // Resize.
                _ => {
                    cluster.repartition_topic("t", 1 + rng.below(8)).unwrap();
                }
            }
            // Every previously committed offset reads back unchanged.
            for (p, want) in committed.iter().enumerate() {
                assert_eq!(
                    cluster.committed("g", "t", p),
                    *want,
                    "partition {p} committed offset changed across a resize"
                );
            }
        }
    });
}
