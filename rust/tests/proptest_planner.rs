//! Property-based invariants over the scaling planner.
//!
//! The planner sits between every policy and every actuation, so its
//! safety story is this suite: across random signal snapshots, random
//! intents and random planner tunings we assert that every emitted
//! [`ScalingPlan`]
//!
//! * **(a) respects the controller's limits** — planned processing
//!   nodes never exceed `max_step` (mirroring
//!   `AutoscalerConfig::max_step`) nor push the fleet past `max_nodes`
//!   (the base allocation plus `AutoscalerConfig::max_extension_nodes`,
//!   exactly how the controller derives the snapshot ceiling);
//! * **(b) respects per-node I/O budgets** — a planned partition count
//!   never oversubscribes `partitions_per_broker_node` across the
//!   broker tier *including* the plan's own co-scheduled broker
//!   extension, and that extension never exceeds `max_broker_step`;
//! * **(c) is well-formed** — shrinks never cut below the fleet floor,
//!   deferred plans carry no steps, steps execute broker → repartition
//!   → processing, and the same inputs always produce the same plan.
//!
//! Like `proptest_invariants.rs`, this is a seeded-random harness (the
//! offline dependency set has no `proptest`): failures print the seed
//! for replay, and `PROPTEST_CASES` scales the case count (the CI
//! `proptest` job runs these suites deeper than the default
//! `cargo test` pass).

use pilot_streaming::autoscale::{
    PlanStep, Planner, PlannerConfig, ScalingIntent, SignalSnapshot,
};
use pilot_streaming::pilot::FrameworkKind;
use pilot_streaming::util::Rng;

/// Case count: `PROPTEST_CASES` env override, else the suite default.
fn cases(default: usize) -> usize {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Run `f` over seeded cases; panic messages carry the seed for replay.
fn check<F: Fn(&mut Rng)>(name: &str, default_cases: usize, f: F) {
    for case in 0..cases(default_cases) {
        let seed = 0xB1A5ED ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {e:?}");
        }
    }
}

const FRAMEWORKS: [FrameworkKind; 4] = [
    FrameworkKind::Kafka,
    FrameworkKind::Spark,
    FrameworkKind::Dask,
    FrameworkKind::Flink,
];

/// A random but internally consistent snapshot (the shape the live
/// probe and the elastic sim both produce).
fn random_snapshot(rng: &mut Rng) -> SignalSnapshot {
    let min_nodes = 1 + rng.below(4);
    // Mirror the controller: the ceiling is the base allocation plus a
    // random AutoscalerConfig::max_extension_nodes.
    let max_extension_nodes = rng.below(8);
    let max_nodes = min_nodes + max_extension_nodes;
    let nodes = min_nodes + rng.below(max_extension_nodes + 1);
    let partitions = 1 + rng.below(200);
    SignalSnapshot {
        t_secs: rng.range_f64(0.0, 10_000.0),
        lag: rng.below(2_000_000) as u64,
        lag_slope: rng.range_f64(-10_000.0, 10_000.0),
        produce_rate: rng.range_f64(0.0, 50_000.0),
        consume_rate: rng.range_f64(0.0, 50_000.0),
        partition_backlog: (0..partitions.min(16)).map(|_| rng.below(10_000) as u64).collect(),
        partitions,
        behind_batches: rng.below(100) as u64,
        last_batch_secs: rng.range_f64(0.0, 10.0),
        window_secs: rng.range_f64(0.05, 120.0),
        nodes,
        min_nodes,
        max_nodes,
        // Uncalibrated about a quarter of the time (cost gate off).
        service_rate_per_node: if rng.below(4) == 0 { 0.0 } else { rng.range_f64(0.1, 5_000.0) },
        broker_nodes: 1 + rng.below(8),
        broker_nic_util: rng.range_f64(0.0, 1.2),
        broker_disk_util: rng.range_f64(0.0, 1.2),
        // Occasionally the tier runs degraded (a dead replica awaiting
        // replacement), so repair plans flow through the invariants too
        // — sometimes with quorum still healthy (under-replicated
        // only), sometimes quorum-degraded (drives repair).
        under_replicated: if rng.below(4) == 0 { rng.below(16) } else { 0 },
        below_min_insync: if rng.below(5) == 0 { rng.below(16) } else { 0 },
        // Placement-debt signals: rack crowding after a failure-domain
        // bounce, and hot-broker load skew — both sometimes firing so
        // reassignment plans flow through the invariants too.
        broker_util_skew: if rng.below(3) == 0 { rng.range_f64(0.0, 1.0) } else { 0.0 },
        rack_skew: if rng.below(3) == 0 { rng.range_f64(0.0, 1.0) } else { 0.0 },
        shard_queue_depths: (0..rng.below(8)).map(|_| rng.below(64) as u64).collect(),
        edge_lags: Vec::new(),
    }
}

fn random_config(rng: &mut Rng) -> PlannerConfig {
    PlannerConfig::default()
        .with_frameworks(
            FRAMEWORKS[rng.below(4)],
            FRAMEWORKS[rng.below(4)],
        )
        .with_max_step(1 + rng.below(8))
        .with_drain_horizon_secs([5.0, 30.0, 120.0, 600.0, 3_600.0][rng.below(5)])
        .with_partitions_per_broker_node(1 + rng.below(24))
        .with_broker_util_threshold(rng.range_f64(0.1, 1.0))
        .with_max_broker_step(rng.below(4))
}

fn random_intent(rng: &mut Rng) -> ScalingIntent {
    match rng.below(4) {
        0 => ScalingIntent::Hold,
        1 => ScalingIntent::ScaleUp(rng.below(24)),
        2 => ScalingIntent::ScaleDown(rng.below(24)),
        _ => ScalingIntent::Repartition {
            partitions: 1 + rng.below(400),
            scale_up: rng.below(24),
        },
    }
}

#[test]
fn plans_respect_limits_budgets_and_shape() {
    check("plan-invariants", 400, |rng| {
        let config = random_config(rng);
        let planner = Planner::new(config.clone());
        // A short random signal sequence under one planner, as the
        // control loop would see it.
        for _ in 0..16 {
            let s = random_snapshot(rng);
            let intent = random_intent(rng);
            let plan = planner.plan(intent, &s);

            // (c) determinism: same inputs, same plan.
            assert_eq!(plan, planner.plan(intent, &s), "plan not deterministic");
            // (c) deferred plans are pure refusals.
            if plan.deferred.is_some() {
                assert!(plan.steps.is_empty(), "deferred plan has steps: {plan:?}");
                continue;
            }

            // (a) controller limits.
            let up = plan.added_processing_nodes();
            assert!(up <= config.max_step, "{up} > max_step {}", config.max_step);
            assert!(
                s.nodes + up <= s.max_nodes,
                "plan pushes fleet to {} past max_nodes {} (max_extension_nodes ceiling)",
                s.nodes + up,
                s.max_nodes
            );

            // (b) broker budget.
            let broker_up = plan.added_broker_nodes();
            assert!(
                broker_up <= config.max_broker_step,
                "{broker_up} > max_broker_step {}",
                config.max_broker_step
            );
            if let Some(target) = plan.repartition_target() {
                assert!(
                    target <= (s.broker_nodes + broker_up) * config.partitions_per_broker_node,
                    "{target} partitions oversubscribe {} brokers x {} budget",
                    s.broker_nodes + broker_up,
                    config.partitions_per_broker_node
                );
                assert!(target >= 1);
            }

            // (c) shrinks never cut below the floor; a plan never mixes
            // growth and shrink.
            let down: usize = plan
                .steps
                .iter()
                .map(|st| match st {
                    PlanStep::ShrinkProcessing { nodes } => *nodes,
                    _ => 0,
                })
                .sum();
            assert!(down <= s.nodes.saturating_sub(s.min_nodes), "shrink below floor");
            assert!(down == 0 || (up == 0 && broker_up == 0), "mixed plan: {plan:?}");

            // (c) step order: broker -> repartition -> processing.
            let pos = |pred: fn(&PlanStep) -> bool| plan.steps.iter().position(pred);
            let b = pos(|st| matches!(st, PlanStep::ExtendBroker { .. }));
            let r = pos(|st| matches!(st, PlanStep::Repartition { .. }));
            let p = pos(|st| matches!(st, PlanStep::ExtendProcessing { .. }));
            if let (Some(b), Some(r)) = (b, r) {
                assert!(b < r, "broker step after repartition: {plan:?}");
            }
            if let (Some(r), Some(p)) = (r, p) {
                assert!(r < p, "repartition after processing step: {plan:?}");
            }
            if let (Some(b), Some(p)) = (b, p) {
                assert!(b < p, "broker step after processing step: {plan:?}");
            }

            // Costs are finite and non-negative.
            for st in &plan.steps {
                if let PlanStep::ExtendBroker { cost, .. }
                | PlanStep::Repartition { cost, .. }
                | PlanStep::ExtendProcessing { cost, .. } = st
                {
                    assert!(cost.lead_secs.is_finite() && cost.lead_secs >= 0.0);
                    assert!(cost.node_secs.is_finite() && cost.node_secs >= 0.0);
                }
                // Placement repair moves replicas on the existing
                // tier: it must never be empty and never commit
                // node-seconds (that would make it an extension).
                if let PlanStep::ReassignReplicas { moves, cost } = st {
                    assert!(*moves >= 1, "empty reassignment step: {plan:?}");
                    assert!(cost.lead_secs.is_finite() && cost.lead_secs >= 0.0);
                    assert_eq!(cost.node_secs, 0.0, "reassignment bought nodes: {plan:?}");
                }
            }
            assert!(plan.expected_drain_msgs.is_finite() && plan.expected_drain_msgs >= 0.0);
        }
    });
}

/// Intents the policy layer can actually emit (via the shipped
/// policies) keep the same invariants when the snapshot sequence is a
/// coherent backlog trajectory rather than white noise.
#[test]
fn plans_hold_limits_across_backlog_trajectories() {
    use pilot_streaming::autoscale::{PartitionElastic, ScalingPolicy, ThresholdPolicy};

    check("plan-trajectory-invariants", 200, |rng| {
        let config = random_config(rng);
        let planner = Planner::new(config.clone());
        let inner = ThresholdPolicy::new(1_000, 100)
            .with_sustain(1 + rng.below(2))
            .with_cooldown_secs(rng.range_f64(0.0, 2.0))
            .with_step(1 + rng.below(8));
        let mut policy = PartitionElastic::new(inner, 1 + rng.below(4));

        let mut s = random_snapshot(rng);
        let mut lag = rng.below(5_000) as i64;
        for tick in 0..64 {
            // Random-walk the backlog; keep the rest of the snapshot.
            lag = (lag + rng.below(2_001) as i64 - 1_000).max(0);
            s.t_secs = tick as f64;
            s.lag = lag as u64;
            s.lag_slope = rng.range_f64(-500.0, 500.0);
            let plan = planner.plan(policy.decide(&s), &s);
            if plan.deferred.is_some() {
                assert!(plan.steps.is_empty());
                continue;
            }
            let up = plan.added_processing_nodes();
            assert!(up <= config.max_step);
            assert!(s.nodes + up <= s.max_nodes);
            assert!(plan.added_broker_nodes() <= config.max_broker_step);
            if let Some(target) = plan.repartition_target() {
                assert!(
                    target
                        <= (s.broker_nodes + plan.added_broker_nodes())
                            * config.partitions_per_broker_node
                );
            }
            // Feed the actuation back so the trajectory stays coherent.
            s.nodes = (s.nodes + up).min(s.max_nodes);
            if let Some(target) = plan.repartition_target() {
                s.partitions = target;
            }
            s.broker_nodes += plan.added_broker_nodes();
            for st in &plan.steps {
                if let PlanStep::ShrinkProcessing { nodes } = st {
                    s.nodes = s.nodes.saturating_sub(*nodes).max(s.min_nodes);
                }
            }
        }
    });
}
