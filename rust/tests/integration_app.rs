//! Application-API integration on the real plane: third-party
//! `DataSource` + `StreamProcessor` implementations running end-to-end
//! through `StreamingApp::launch()` / `drain_and_stop()` — without
//! touching `miniapp` — plus the drain protocol's no-loss guarantees
//! under an in-flight burst.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use pilot_streaming::app::{
    CountingProcessor, DataSource, SourceSpec, SourceStream, StageSpec, StreamProcessor,
    StreamingApp,
};
use pilot_streaming::broker::{Consumer, ConsumerConfig, Record};
use pilot_streaming::cluster::Machine;
use pilot_streaming::engine::TaskContext;
use pilot_streaming::miniapp::{MassConfig, SourceKind};
use pilot_streaming::pilot::{FrameworkKind, KafkaDescription, PilotComputeService};
use pilot_streaming::Result;

// ---------------------------------------------------------------------
// A third-party mini-app: fixed-width sequence records (no `miniapp`
// wire format anywhere) summed by a stateful processor.
// ---------------------------------------------------------------------

struct SeqSource;

struct SeqStream {
    stream: u64,
}

impl DataSource for SeqSource {
    fn name(&self) -> &str {
        "seq"
    }

    fn open(&self, stream: u64) -> Box<dyn SourceStream> {
        Box::new(SeqStream { stream })
    }
}

impl SourceStream for SeqStream {
    fn next_message(&mut self, seq: u64) -> Vec<u8> {
        let mut bytes = Vec::with_capacity(16);
        bytes.extend_from_slice(&self.stream.to_le_bytes());
        bytes.extend_from_slice(&seq.to_le_bytes());
        bytes
    }
}

#[derive(Default)]
struct SumProcessor {
    count: AtomicU64,
    seq_sum: AtomicU64,
    warmed: AtomicU64,
}

impl StreamProcessor for SumProcessor {
    fn name(&self) -> &str {
        "sum"
    }

    fn warmup(&self) -> Result<()> {
        self.warmed.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn process_window(&self, _ctx: &TaskContext, window: &[Record]) -> Result<()> {
        for r in window {
            let bytes: &[u8] = &r.value;
            assert_eq!(bytes.len(), 16, "third-party frame size");
            let seq = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
            self.count.fetch_add(1, Ordering::Relaxed);
            self.seq_sum.fetch_add(seq, Ordering::Relaxed);
        }
        Ok(())
    }
}

fn service(nodes: usize) -> Arc<PilotComputeService> {
    Arc::new(PilotComputeService::new(Machine::unthrottled(nodes)))
}

#[test]
fn third_party_source_and_processor_run_end_to_end() {
    let service = service(4);
    let processor = Arc::new(SumProcessor::default());
    let app = StreamingApp::builder()
        .broker(KafkaDescription::new(1), &[("frames", 3)])
        .source(
            SourceSpec::new("seq", "frames", Arc::new(SeqSource))
                .with_producers(3)
                .with_total_messages(20),
        )
        .stage(
            StageSpec::new("sum", "frames", processor.clone())
                .with_window(Duration::from_millis(20)),
        )
        .build()
        .unwrap();

    let handle = app.launch(&service).unwrap();
    assert_eq!(processor.warmed.load(Ordering::Relaxed), 1, "warmup ran once");

    // Broker + stage + source pilots, each with a startup breakdown.
    let startups = handle.startup_breakdowns();
    assert_eq!(startups.len(), 3);
    assert!(startups.iter().all(|(_, s)| s.total_secs() > 0.0));
    assert!(startups[0].0.contains("kafka"), "broker first: {startups:?}");

    // 20 over 3 producers: 7 + 7 + 6 — the remainder is distributed.
    let produced = handle.await_sources().unwrap();
    assert_eq!(produced.len(), 1);
    assert_eq!(produced[0].messages, 20);

    let report = handle.drain_and_stop().unwrap();
    assert!(report.drained);
    assert_eq!(report.produced_messages(), 20);
    assert_eq!(report.processed_messages(), 20, "no loss through the app");
    assert_eq!(report.terminal_lag(), 0);
    assert_eq!(processor.count.load(Ordering::Relaxed), 20);
    // Per-producer seqs are 0..7, 0..7, 0..6: 21 + 21 + 15.
    assert_eq!(processor.seq_sum.load(Ordering::Relaxed), 57);
    assert_eq!(report.stages[0].errors, 0);

    // Everything released.
    assert_eq!(service.machine().free_nodes(), 4);
}

#[test]
fn drain_and_stop_races_an_inflight_burst_without_loss() {
    let service = service(4);
    let counter = CountingProcessor::new();
    // A slow trickle with a huge budget: the fence will cut production
    // mid-stream, and drain must still account for every landed record.
    let mut cfg = MassConfig::new(SourceKind::KmeansStatic, "burst");
    cfg.points_per_msg = 50;
    cfg.target_msg_bytes = Some(0);
    let app = StreamingApp::builder()
        .broker(KafkaDescription::new(1), &[("burst", 2)])
        .source(
            SourceSpec::mass(cfg)
                .with_producers(2)
                .with_total_messages(100_000)
                .with_rate(200.0),
        )
        .stage(
            StageSpec::new("count", "burst", counter.clone())
                .with_window(Duration::from_millis(20)),
        )
        .build()
        .unwrap();

    let handle = app.launch(&service).unwrap();
    // Let some of the burst flow, then stop mid-flight.
    std::thread::sleep(Duration::from_millis(300));

    // Regression (commit lag-gauge refresh): a drain loop that commits
    // and then samples `lag()` must see lag recomputed against the live
    // backlog — `commit` used to leave the gauge at its last refresh,
    // so an observer here would have read the join-time value forever.
    // An independent audit group watches the same racing topic; no poll
    // happens between the join and the commit, so only the commit-path
    // refresh can move the gauge.
    let cluster = handle.cluster().clone();
    let audit = Consumer::join(
        cluster.clone(),
        "burst",
        "audit",
        0,
        ConsumerConfig {
            fetch_timeout: Duration::from_millis(1),
            auto_commit: false,
            ..Default::default()
        },
    )
    .unwrap();
    let at_join = audit.lag();
    // Wait (bounded) until the still-running source lands more records
    // past the join-time snapshot.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while cluster.group_lag("audit", "burst").unwrap() <= at_join
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(10));
    }
    let floor = cluster.group_lag("audit", "burst").unwrap();
    assert!(floor > at_join, "source kept producing under the audit group");
    audit.commit();
    assert!(
        audit.lag() >= floor,
        "commit must recompute the lag gauge ({} >= {floor}); it used to stay at the \
         join-time {at_join}",
        audit.lag()
    );
    drop(audit);

    let report = handle.drain_and_stop().unwrap();

    assert!(report.drained, "drain timed out");
    assert_eq!(report.terminal_lag(), 0, "lag must be fully drained");
    let produced = report.produced_messages();
    assert!(produced > 0, "nothing flowed before the fence");
    assert!(
        produced < 100_000,
        "fence did not cut the burst short: {produced}"
    );
    assert_eq!(
        report.processed_messages(),
        produced,
        "every landed message must be processed"
    );
    assert_eq!(counter.messages(), produced);

    // A second call is a clean no-op returning the cached report.
    let again = handle.drain_and_stop().unwrap();
    assert_eq!(again.produced_messages(), produced);
    assert_eq!(again.processed_messages(), report.processed_messages());
    assert_eq!(service.machine().free_nodes(), 4, "no pilots leaked");
}

#[test]
fn stats_and_extend_work_while_running() {
    let service = service(5);
    let counter = CountingProcessor::new();
    let app = StreamingApp::builder()
        .broker(KafkaDescription::new(1), &[("t", 2)])
        .source(
            SourceSpec::new("seq", "t", Arc::new(SeqSource))
                .with_producers(1)
                .with_total_messages(5),
        )
        .stage(StageSpec::new("count", "t", counter).with_window(Duration::from_millis(20)))
        .build()
        .unwrap();
    let handle = app.launch(&service).unwrap();

    // Listing 4 at the application level: grow the stage mid-run.
    let ext = handle.extend("count", 1).unwrap();
    assert!(ext.id().contains("spark"));
    assert!(handle.extend("ghost", 1).is_err());
    assert!(handle.lag("ghost").is_err());

    handle.await_sources().unwrap();
    let live = handle.stats();
    assert!(!live.drained, "live snapshot is not terminal");
    assert_eq!(live.sources[0].messages, 5);

    let report = handle.drain_and_stop().unwrap();
    assert!(report.drained);
    assert_eq!(report.processed_messages(), 5);
    // The manual extension was released with everything else.
    assert_eq!(service.machine().free_nodes(), 5);
}

#[test]
fn dask_backed_stage_processes_the_same_windows() {
    // Framework interoperability: the same stage spec runs on a
    // Dask-managed task pool instead of the Spark micro-batch engine.
    let service = service(4);
    let counter = CountingProcessor::new();
    let app = StreamingApp::builder()
        .broker(KafkaDescription::new(1), &[("t", 2)])
        .source(
            SourceSpec::new("seq", "t", Arc::new(SeqSource))
                .with_producers(2)
                .with_total_messages(9),
        )
        .stage(
            StageSpec::new("count", "t", counter.clone())
                .with_framework(FrameworkKind::Dask)
                .with_window(Duration::from_millis(20)),
        )
        .build()
        .unwrap();
    let handle = app.launch(&service).unwrap();
    handle.await_sources().unwrap();
    let report = handle.drain_and_stop().unwrap();
    assert!(report.drained);
    assert_eq!(report.processed_messages(), 9);
    assert_eq!(counter.messages(), 9);
    assert_eq!(service.machine().free_nodes(), 4);
}

#[test]
fn racked_spec_labels_failure_domains_at_launch() {
    use pilot_streaming::app::ReplicationSpec;
    let service = service(6);
    let counter = CountingProcessor::new();
    let app = StreamingApp::builder()
        .broker(KafkaDescription::new(2), &[("t", 4)])
        .replication(ReplicationSpec::new(2))
        .racks(2)
        .source(
            SourceSpec::new("seq", "t", Arc::new(SeqSource))
                .with_producers(1)
                .with_total_messages(8),
        )
        .stage(StageSpec::new("count", "t", counter).with_window(Duration::from_millis(20)))
        .build()
        .unwrap();
    let handle = app.launch(&service).unwrap();

    // launch_inner labels the tier before creating topics, so every
    // factor-2 replica set spans both domains — no fallback placements.
    let cluster = handle.cluster();
    let brokers = cluster.broker_nodes();
    assert_eq!(brokers.len(), 2);
    let racks: Vec<_> = brokers.iter().map(|&b| cluster.rack_of(b).unwrap()).collect();
    assert_eq!(racks, vec![0, 1], "round-robin rack striping");
    assert_eq!(cluster.rack_constraint_violations(), 0);

    handle.await_sources().unwrap();
    let report = handle.drain_and_stop().unwrap();
    assert!(report.drained);
    assert_eq!(report.processed_messages(), 8);
    assert_eq!(service.machine().free_nodes(), 6);
}

#[test]
fn launch_failure_releases_every_started_pilot() {
    struct FailingWarmup;
    impl StreamProcessor for FailingWarmup {
        fn warmup(&self) -> Result<()> {
            Err(pilot_streaming::Error::App("no artifacts".into()))
        }
        fn process_window(&self, _: &TaskContext, _: &[Record]) -> Result<()> {
            Ok(())
        }
    }
    let service = service(4);
    let app = StreamingApp::builder()
        .broker(KafkaDescription::new(1), &[("t", 1)])
        .source(
            SourceSpec::new("seq", "t", Arc::new(SeqSource)).with_total_messages(1),
        )
        .stage(StageSpec::new("fail", "t", Arc::new(FailingWarmup)))
        .build()
        .unwrap();
    let err = app.launch(&service).unwrap_err();
    assert!(err.to_string().contains("no artifacts"), "{err}");
    assert_eq!(service.machine().free_nodes(), 4, "partial launch leaked nodes");
}
