//! Cross-language round trip: JAX -> HLO text -> PJRT-in-Rust.
//!
//! `aot.py` writes golden test vectors (`testvectors/<name>.in*.bin` /
//! `.out*.bin`) produced by live-JAX evaluation of every artifact.
//! These tests execute the compiled HLO artifacts through the Rust
//! runtime on the same inputs and assert the numbers match — the core
//! correctness signal for the serving path.  Requires `make artifacts`.

use pilot_streaming::runtime::{ModelRuntime, Tensor};

/// AOT artifacts are a build product (`make artifacts`, needs the JAX
/// toolchain) and PJRT execution needs the `xla` cargo feature; in their
/// absence these golden tests skip rather than fail, so plain
/// `cargo test` stays green on a bare checkout.
fn runtime() -> Option<ModelRuntime> {
    let rt = ModelRuntime::load_default().ok()?;
    if rt.warmup("gridrec").is_err() {
        eprintln!("skipping: PJRT executor unavailable (xla feature off)");
        return None;
    }
    Some(rt)
}

fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    let mut worst = 0.0f32;
    let mut worst_idx = 0;
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let err = (g - w).abs();
        let tol = atol + rtol * w.abs();
        if err - tol > worst {
            worst = err - tol;
            worst_idx = i;
        }
    }
    assert!(
        worst <= 0.0,
        "{what}: mismatch at {worst_idx}: got {} want {} (excess {worst})",
        got[worst_idx],
        want[worst_idx]
    );
}

fn roundtrip(name: &str) {
    let Some(rt) = runtime() else { return };
    let meta = rt.meta(name).unwrap().clone();
    let inputs: Vec<Vec<f32>> = (0..meta.inputs.len())
        .map(|i| {
            rt.read_f32_file(&format!("testvectors/{name}.in{i}.bin"))
                .unwrap()
        })
        .collect();
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let outs = rt.execute(name, &refs).unwrap();
    assert_eq!(outs.len(), meta.outputs.len(), "{name}: output arity");
    for (i, (out, sig)) in outs.iter().zip(&meta.outputs).enumerate() {
        let what = format!("{name}.out{i}");
        match out {
            Tensor::F32(got) => {
                let want = rt
                    .read_f32_file(&format!("testvectors/{name}.out{i}.bin"))
                    .unwrap();
                assert_allclose(got, &want, 1e-4, 1e-4, &what);
            }
            Tensor::I32(got) => {
                let want = rt
                    .read_i32_file(&format!("testvectors/{name}.out{i}.bin"))
                    .unwrap();
                assert_eq!(got, &want, "{what}: int mismatch");
            }
        }
        assert_eq!(out.len(), sig.elements(), "{what}: shape");
    }
}

#[test]
fn golden_kmeans_score() {
    roundtrip("kmeans_score");
}

#[test]
fn golden_kmeans_update() {
    roundtrip("kmeans_update");
}

#[test]
fn golden_gridrec() {
    roundtrip("gridrec");
}

#[test]
fn golden_mlem() {
    roundtrip("mlem");
}

#[test]
fn golden_radon() {
    roundtrip("radon");
}

#[test]
fn gridrec_of_template_matches_phantom() {
    // Full physical pipeline: radon(phantom) -> gridrec -> ~phantom.
    let Some(rt) = runtime() else { return };
    let tomo = rt.manifest().tomo.clone();
    let sino = rt.read_f32_file("template_sinogram.bin").unwrap();
    let phantom = rt.read_f32_file("phantom.bin").unwrap();
    let outs = rt.execute("gridrec", &[&sino]).unwrap();
    let img = outs[0].as_f32().unwrap();
    let (h, w) = (tomo.img_h, tomo.img_w);
    let mut se = 0.0f64;
    for i in 16..h - 16 {
        for j in 16..w - 16 {
            let d = (img[i * w + j] - phantom[i * w + j]) as f64;
            se += d * d;
        }
    }
    let rmse = (se / ((h - 32) * (w - 32)) as f64).sqrt();
    assert!(rmse < 0.12, "gridrec rmse {rmse}");
}

#[test]
fn mlem_reconstruction_is_nonnegative_and_bounded() {
    let Some(rt) = runtime() else { return };
    let sino = rt.read_f32_file("template_sinogram.bin").unwrap();
    let outs = rt.execute("mlem", &[&sino]).unwrap();
    let img = outs[0].as_f32().unwrap();
    assert!(img.iter().all(|v| *v >= 0.0), "EM preserves nonnegativity");
    assert!(img.iter().all(|v| *v < 100.0), "EM bounded");
    assert!(img.iter().any(|v| *v > 0.1), "EM found structure");
}

#[test]
fn execute_validates_shapes_and_names() {
    let Some(rt) = runtime() else { return };
    assert!(rt.execute("nope", &[]).is_err(), "unknown artifact");
    let short = vec![0.0f32; 3];
    assert!(
        rt.execute("gridrec", &[&short]).is_err(),
        "wrong input length"
    );
    let sino = vec![0.1f32; rt.manifest().tomo.n_angles * rt.manifest().tomo.n_det];
    assert!(
        rt.execute("gridrec", &[&sino, &sino]).is_err(),
        "wrong arity"
    );
}

#[test]
fn runtime_is_shareable_across_threads() {
    // TLS clients: each thread compiles its own executable and gets
    // identical numbers.
    let Some(rt) = runtime() else { return };
    let sino = std::sync::Arc::new(rt.read_f32_file("template_sinogram.bin").unwrap());
    let expect = rt.execute("gridrec", &[&sino]).unwrap()[0]
        .as_f32()
        .unwrap()
        .to_vec();
    let mut handles = Vec::new();
    for _ in 0..2 {
        let rt = rt.clone();
        let sino = sino.clone();
        let expect = expect.clone();
        handles.push(std::thread::spawn(move || {
            let outs = rt.execute("gridrec", &[&sino]).unwrap();
            assert_eq!(outs[0].as_f32().unwrap(), expect.as_slice());
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn calibrate_returns_positive_costs() {
    let Some(rt) = runtime() else { return };
    let secs = rt.calibrate("kmeans_update", 3).unwrap();
    assert!(secs > 0.0 && secs < 1.0, "kmeans_update {secs}s");
}
