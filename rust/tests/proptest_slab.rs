//! Property-based tests over the zero-copy shared-slab log.
//!
//! The zero-copy rewrite (PR 4) hands out [`SharedSlice`] views into
//! `Arc`-backed segment slabs instead of copied payloads.  These
//! properties pin the guarantees that make that sound:
//!
//! * views stay **valid and byte-identical** across any interleaving of
//!   appends, segment rolls, and retention drops — including views of
//!   records the log has since evicted;
//! * a reader that raced retention gets a clean `Error`, never a panic
//!   and never someone else's bytes;
//! * concurrent appenders and readers agree on content (single-writer
//!   slabs + `Release`/`Acquire` committed lengths).
//!
//! Same seeded-random harness as `proptest_invariants.rs`
//! (`PROPTEST_CASES` scales depth in CI).

use std::sync::Arc;

use pilot_streaming::broker::{LogConfig, PartitionLog, Record};
use pilot_streaming::util::Rng;

fn cases() -> usize {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200)
}

fn check<F: Fn(&mut Rng)>(name: &str, f: F) {
    for case in 0..cases() {
        let seed = 0x51AB ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {e:?}");
        }
    }
}

/// Deterministic payload for an offset: length and bytes derive from
/// the offset alone, so any thread can verify any record it sees.
fn pattern(offset: u64) -> Vec<u8> {
    let len = 1 + (offset % 29) as usize;
    (0..len)
        .map(|i| (offset.wrapping_mul(31).wrapping_add(i as u64) % 251) as u8)
        .collect()
}

#[test]
fn prop_views_valid_across_roll_and_retention_interleavings() {
    check("slab-view-validity", |rng| {
        // Tiny segments + tight retention force frequent rolls and
        // evictions inside even a short run.
        let log = PartitionLog::new(LogConfig {
            segment_bytes: 8 + rng.below(48),
            retention_bytes: Some(64 + rng.below(192)),
        });
        let mut held: Vec<Record> = Vec::new();
        let mut appended = 0u64;
        for _ in 0..rng.below(80) + 10 {
            match rng.below(3) {
                // Append a batch (may roll segments and evict old ones).
                0 | 1 => {
                    let n = 1 + rng.below(4) as u64;
                    let batch: Vec<Vec<u8>> =
                        (0..n).map(|i| pattern(appended + i)).collect();
                    let base =
                        log.append_batch(batch.iter().map(|v| v.as_slice()), appended);
                    assert_eq!(base, appended, "offsets stay dense");
                    appended += n;
                }
                // Read a random retained range and hold some views.
                _ => {
                    if appended == 0 {
                        continue;
                    }
                    let from = log.start_offset() + rng.below(8) as u64;
                    match log.read(from, 1 + rng.below(256)) {
                        Ok(recs) => {
                            for r in recs {
                                assert_eq!(
                                    r.value,
                                    pattern(r.offset),
                                    "offset {} corrupt at read time",
                                    r.offset
                                );
                                if rng.below(3) == 0 {
                                    held.push(r);
                                }
                            }
                        }
                        // `from` raced past retention — clean error only.
                        Err(e) => {
                            assert!(
                                e.to_string().contains("retention"),
                                "unexpected error: {e}"
                            );
                        }
                    }
                }
            }
            // Every held view stays byte-identical no matter what the
            // log has rolled or evicted since it was taken.
            for r in &held {
                assert_eq!(
                    r.value,
                    pattern(r.offset),
                    "held view of offset {} changed (start_offset now {})",
                    r.offset,
                    log.start_offset()
                );
            }
        }
    });
}

#[test]
fn prop_fetch_started_before_eviction_still_reads_its_slab() {
    check("slab-eviction-liveness", |rng| {
        let log = PartitionLog::new(LogConfig {
            segment_bytes: 16 + rng.below(32),
            retention_bytes: Some(48 + rng.below(64)),
        });
        // Seed some records and take views of the earliest ones — the
        // "fetch started before retention eviction".
        for off in 0..4u64 {
            log.append_batch([pattern(off).as_slice()], off);
        }
        let early = log.read(0, usize::MAX).unwrap();
        assert!(!early.is_empty());
        // Append until offset 0 is long evicted.
        let mut off = 4u64;
        while log.start_offset() == 0 {
            log.append_batch([pattern(off).as_slice()], off);
            off += 1;
            assert!(off < 10_000, "retention never kicked in");
        }
        // New reads below the start error cleanly on both entry points.
        let err = log.read(0, usize::MAX).unwrap_err();
        assert!(err.to_string().contains("retention"), "{err}");
        // The pre-eviction views still read their original slab bytes.
        for r in &early {
            assert_eq!(r.value, pattern(r.offset), "evicted view offset {}", r.offset);
        }
    });
}

#[test]
fn prop_concurrent_append_roll_retention_and_reads_agree() {
    // Fewer, heavier cases: each spins up real threads.
    let deep = (cases() / 20).clamp(3, 30);
    for case in 0..deep {
        let seed = 0xC0AB ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        let total = 400 + rng.below(400) as u64;
        let log = Arc::new(PartitionLog::new(LogConfig {
            segment_bytes: 64 + rng.below(128),
            retention_bytes: Some(512 + rng.below(512)),
        }));
        let writer = {
            let log = log.clone();
            std::thread::spawn(move || {
                for off in 0..total {
                    log.append_batch([pattern(off).as_slice()], off);
                }
            })
        };
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let log = log.clone();
                std::thread::spawn(move || {
                    let mut seen = 0u64;
                    let mut held: Option<Record> = None;
                    while seen < total {
                        let from = log.start_offset().max(seen);
                        match log.read(from, 512) {
                            Ok(recs) => {
                                for r in &recs {
                                    assert_eq!(
                                        r.value,
                                        pattern(r.offset),
                                        "offset {}",
                                        r.offset
                                    );
                                }
                                if let Some(last) = recs.last() {
                                    seen = last.offset + 1;
                                    if held.is_none() {
                                        held = recs.first().cloned();
                                    }
                                }
                            }
                            // Raced retention: resync to the new start.
                            Err(_) => seen = log.start_offset(),
                        }
                        // A view held across the whole run never decays.
                        if let Some(h) = &held {
                            assert_eq!(h.value, pattern(h.offset));
                        }
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        for r in readers {
            r.join().unwrap();
        }
    }
}
