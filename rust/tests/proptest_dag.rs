//! Property-based invariants over dataflow DAGs (split/merge chains).
//!
//! A DAG app chains engine jobs through broker topics: every hop
//! re-emits its input records downstream through a keyed producer that
//! flushes *before* the hop commits its input offsets.  Across random
//! 2-branch split/merge topologies under produce and repartition
//! churn, we assert
//!
//! * **(a) exactly-once end-to-end** — every record produced at the
//!   head is observed exactly once at the sink topic, across every
//!   intermediate hop and any number of mid-flight repartitions of any
//!   edge topic;
//! * **(b) per-key order end-to-end** — the key-hash split pins each
//!   key to one branch, so each key's records arrive at the sink in
//!   produce order even though the branches race each other;
//! * **(c) topological drain honesty** — `drain_and_stop` called while
//!   records are still in flight (and even with a repartition landed
//!   immediately before it) may only report `drained` once *every* hop
//!   has processed its full share: the per-stage reports must conserve
//!   the record count hop by hop, with zero residual lag anywhere.
//!
//! Like the other `proptest_*` suites this is a seeded-random harness
//! (the offline dependency set has no `proptest`): failures print the
//! seed for replay and `PROPTEST_CASES` scales the case count.  Each
//! case launches a full app (broker pilot + one engine job per DAG
//! node), so the deep-CI multiplier is capped to keep the job bounded.

use std::sync::Arc;
use std::time::Duration;

use pilot_streaming::app::{
    CountingProcessor, MergeSpec, RelayProcessor, SplitRoute, SplitSpec, StageSpec, StreamingApp,
};
use pilot_streaming::broker::{
    Consumer, ConsumerConfig, PartitionRecord, Partitioner, Producer, ProducerConfig,
};
use pilot_streaming::cluster::Machine;
use pilot_streaming::pilot::{KafkaDescription, PilotComputeService};
use pilot_streaming::util::Rng;

/// Case count: `PROPTEST_CASES` env override (capped — every case is a
/// full app launch, not a bare cluster), else the suite default.
fn cases(default: usize) -> usize {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
        .min(40)
}

/// Run `f` over seeded cases; panic messages carry the seed for replay.
fn check<F: Fn(&mut Rng)>(name: &str, default_cases: usize, f: F) {
    for case in 0..cases(default_cases) {
        let seed = 0xD00F ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {e:?}");
        }
    }
}

fn encode(key: usize, seq: u32) -> Vec<u8> {
    vec![
        key as u8,
        (seq >> 24) as u8,
        (seq >> 16) as u8,
        (seq >> 8) as u8,
        seq as u8,
    ]
}

fn decode(value: &[u8]) -> (usize, u32) {
    (
        value[0] as usize,
        u32::from_be_bytes([value[1], value[2], value[3], value[4]]),
    )
}

fn consumer_config() -> ConsumerConfig {
    ConsumerConfig {
        fetch_timeout: Duration::from_millis(1),
        ..Default::default()
    }
}

/// Invariant (b): each key's records arrive in dense produce order.
fn observe(recs: Vec<PartitionRecord>, consumed_seq: &mut [u32], consumed_total: &mut usize) {
    for r in recs {
        let (k, seq) = decode(&r.record.value);
        assert_eq!(
            seq, consumed_seq[k],
            "key {k}: expected seq {} next, saw {seq} (reorder/dup/loss)",
            consumed_seq[k]
        );
        consumed_seq[k] += 1;
        *consumed_total += 1;
    }
}

/// A randomized 2-branch DAG: optionally a relay chain hop in front,
/// then a key-hash split onto hot/cold, per-branch relay hops, a merge
/// back onto `out`, and a counting sink.  Records enter at `head`
/// (externally produced) and surface at `out`.
fn build_dag(rng: &mut Rng) -> (StreamingApp, &'static str, bool) {
    let window = Duration::from_millis(10);
    let with_chain = rng.below(2) == 0;
    let head = if with_chain { "in" } else { "frames" };
    let parts = |rng: &mut Rng| 1 + rng.below(3);
    let mut topics: Vec<(&str, usize)> = vec![
        ("frames", parts(rng)),
        ("hot", parts(rng)),
        ("cold", parts(rng)),
        ("out", parts(rng)),
    ];
    if with_chain {
        topics.push(("in", parts(rng)));
    }
    let mut b = StreamingApp::builder().broker(KafkaDescription::new(1), &topics);
    if with_chain {
        b = b.stage(
            StageSpec::new("reconstruct", "in", RelayProcessor::new(1))
                .with_window(window)
                .with_output_topic("frames"),
        );
    }
    let app = b
        .split(
            SplitSpec::new("route", "frames", &["hot", "cold"], SplitRoute::KeyHash)
                .with_key_bytes(1)
                .with_window(window),
        )
        .merge(
            MergeSpec::new("fan-in", &["hot", "cold"], "out")
                .with_key_bytes(1)
                .with_window(window),
        )
        .stage(StageSpec::new("archive", "out", CountingProcessor::new()).with_window(window))
        .drain_timeout(Duration::from_secs(60))
        .build()
        .expect("randomized DAG spec is always valid");
    (app, head, with_chain)
}

/// Edge topics eligible for mid-flight repartition churn.
const EDGES: [&str; 5] = ["in", "frames", "hot", "cold", "out"];

/// The flagship DAG property: produce keyed bursts at the head while
/// randomly repartitioning every edge topic, then observe the sink
/// topic with an independent probe group — every record arrives
/// exactly once, per key in order, across all hops.
#[test]
fn prop_dag_split_merge_exactly_once_ordered_under_churn() {
    check("dag-split-merge-churn", 8, |rng| {
        let n_keys = 2 + rng.below(6);
        let (app, head, with_chain) = build_dag(rng);
        let service = Arc::new(PilotComputeService::new(Machine::unthrottled(8)));
        let handle = app.launch(&service).unwrap();
        let cluster = handle.cluster().clone();

        let mut producer = Producer::new(
            cluster.clone(),
            head,
            1,
            ProducerConfig {
                batch_bytes: if rng.below(2) == 0 { 1 } else { 24 },
                partitioner: Partitioner::Keyed,
                ..Default::default()
            },
        )
        .unwrap();
        // Independent probe group on the sink topic: the stage groups
        // drain through the engine, the probe watches the raw records.
        let mut probe = Consumer::join(cluster.clone(), "out", "probe", 2, consumer_config())
            .unwrap();

        let mut produced_seq = vec![0u32; n_keys];
        let mut consumed_seq = vec![0u32; n_keys];
        let mut produced_total = 0usize;
        let mut consumed_total = 0usize;

        let steps = 8 + rng.below(16);
        for _ in 0..steps {
            match rng.below(8) {
                // Produce a keyed burst at the head of the DAG.
                0..=4 => {
                    for _ in 0..1 + rng.below(8) {
                        let k = rng.below(n_keys);
                        let seq = produced_seq[k];
                        produced_seq[k] += 1;
                        producer.send(Some(&[k as u8]), encode(k, seq)).unwrap();
                        produced_total += 1;
                    }
                    if rng.below(2) == 0 {
                        producer.flush().unwrap();
                    }
                }
                // Repartition a random edge topic mid-flight.
                5 | 6 => {
                    let t = EDGES[rng.below(if with_chain { 5 } else { 4 })
                        + usize::from(!with_chain)];
                    cluster.repartition_topic(t, 1 + rng.below(6)).unwrap();
                }
                // Poll the probe a few times.
                _ => {
                    for _ in 0..1 + rng.below(4) {
                        let recs = probe.poll().unwrap();
                        observe(recs, &mut consumed_seq, &mut consumed_total);
                    }
                }
            }
        }
        producer.flush().unwrap();

        // Drain the probe: every produced record must surface at the
        // sink topic exactly once (the hops in between re-emit 1:1).
        let mut idle_rounds = 0;
        while consumed_total < produced_total && idle_rounds < 500 {
            let recs = probe.poll().unwrap();
            if recs.is_empty() {
                idle_rounds += 1;
                std::thread::sleep(Duration::from_millis(5));
            } else {
                idle_rounds = 0;
            }
            observe(recs, &mut consumed_seq, &mut consumed_total);
        }
        assert_eq!(
            consumed_total, produced_total,
            "exactly-once violated end-to-end: {consumed_total} observed at the sink \
             of {produced_total} produced at the head"
        );
        assert_eq!(consumed_seq, produced_seq, "per-key completeness end-to-end");

        // And the topological drain agrees: zero residual lag anywhere.
        let report = handle.drain_and_stop().unwrap();
        assert!(report.drained, "drain timed out with records accounted for");
        for s in &report.stages {
            assert_eq!(s.lag, 0, "stage '{}' drained with residual lag", s.name);
        }
    });
}

/// Invariant (c): `drain_and_stop` called while records are still in
/// flight — possibly with a repartition landed right before it — may
/// only report `drained` once every hop processed its full share.  The
/// per-stage reports must conserve the record count hop by hop.
#[test]
fn prop_dag_topological_drain_never_lies() {
    check("dag-topological-drain", 8, |rng| {
        let n_keys = 2 + rng.below(6);
        let (app, head, with_chain) = build_dag(rng);
        let service = Arc::new(PilotComputeService::new(Machine::unthrottled(8)));
        let handle = app.launch(&service).unwrap();
        let cluster = handle.cluster().clone();

        let mut producer = Producer::new(
            cluster.clone(),
            head,
            1,
            ProducerConfig {
                batch_bytes: 1,
                partitioner: Partitioner::Keyed,
                ..Default::default()
            },
        )
        .unwrap();

        let mut produced_total = 0u64;
        let mut produced_seq = vec![0u32; n_keys];
        for _ in 0..4 + rng.below(40) {
            let k = rng.below(n_keys);
            let seq = produced_seq[k];
            produced_seq[k] += 1;
            producer.send(Some(&[k as u8]), encode(k, seq)).unwrap();
            produced_total += 1;
        }
        // Half the cases land a repartition between the last produce
        // and the drain: the in-flight epoch transition must not let
        // the drain read a stale lag-zero off retired partitions.
        if rng.below(2) == 0 {
            let t = EDGES[rng.below(if with_chain { 5 } else { 4 }) + usize::from(!with_chain)];
            cluster.repartition_topic(t, 1 + rng.below(6)).unwrap();
        }

        // Drain immediately: everything is still in flight.
        let report = handle.drain_and_stop().unwrap();
        assert!(report.drained, "drain timed out");
        let stage = |name: &str| {
            report
                .stages
                .iter()
                .find(|s| s.name == name)
                .unwrap_or_else(|| panic!("no stage report for '{name}'"))
        };

        // Hop-by-hop conservation: a drain that returned with records
        // in flight upstream would under-count every hop downstream.
        if with_chain {
            let r = stage("reconstruct");
            assert_eq!(r.processed_messages, produced_total, "chain hop lost records");
            assert_eq!(r.emitted_messages, produced_total, "chain hop dropped emissions");
        }
        let route = stage("route");
        assert_eq!(route.processed_messages, produced_total, "split under-consumed");
        assert_eq!(route.emitted_messages, produced_total, "split dropped records");
        let legs = [stage("fan-in:hot"), stage("fan-in:cold")];
        assert_eq!(
            legs.iter().map(|s| s.processed_messages).sum::<u64>(),
            produced_total,
            "merge legs under-consumed the branches"
        );
        assert_eq!(
            legs.iter().map(|s| s.emitted_messages).sum::<u64>(),
            produced_total,
            "merge legs dropped records"
        );
        let archive = stage("archive");
        assert_eq!(
            archive.processed_messages, produced_total,
            "drain reported done with upstream records in flight"
        );
        for s in &report.stages {
            assert_eq!(s.lag, 0, "stage '{}' drained with residual lag", s.name);
        }
    });
}
