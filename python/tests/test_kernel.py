"""L1 correctness: Pallas kernels vs. pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/seeds for every kernel and asserts
``assert_allclose`` against ``ref.py`` — the core correctness signal
for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import params
from compile.kernels import kmeans, ref, tomo

SETTINGS = dict(max_examples=20, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# kmeans_assign
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_blocks=st.integers(1, 6),
    block=st.sampled_from([8, 50, 128]),
    d=st.integers(1, 8),
    k=st.integers(1, 16),
)
def test_kmeans_assign_matches_ref(seed, n_blocks, block, d, k):
    rng = _rng(seed)
    n = n_blocks * block
    pts = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    a_pl, d_pl = kmeans.kmeans_assign(pts, cen, block=block)
    a_rf, d_rf = ref.kmeans_assign_ref(pts, cen)
    # Distances must agree tightly; assignments may only differ where two
    # centroids are (near-)equidistant, which random draws make measure-zero.
    assert_allclose(np.asarray(d_pl), np.asarray(d_rf), rtol=1e-4, atol=1e-5)
    assert np.array_equal(np.asarray(a_pl), np.asarray(a_rf))


def test_kmeans_assign_production_shape():
    rng = _rng(7)
    pts = jnp.asarray(
        rng.normal(size=(params.KMEANS_POINTS, params.KMEANS_DIM)).astype(np.float32)
    )
    cen = jnp.asarray(
        rng.normal(size=(params.KMEANS_K, params.KMEANS_DIM)).astype(np.float32)
    )
    a_pl, d_pl = kmeans.kmeans_assign(pts, cen, block=params.KMEANS_BLOCK)
    a_rf, d_rf = ref.kmeans_assign_ref(pts, cen)
    assert np.array_equal(np.asarray(a_pl), np.asarray(a_rf))
    assert_allclose(np.asarray(d_pl), np.asarray(d_rf), rtol=1e-4, atol=1e-5)


def test_kmeans_assign_rejects_ragged_block():
    pts = jnp.zeros((10, 3), jnp.float32)
    cen = jnp.zeros((2, 3), jnp.float32)
    with pytest.raises(ValueError, match="not a multiple"):
        kmeans.kmeans_assign(pts, cen, block=3)


def test_kmeans_assign_single_centroid():
    rng = _rng(1)
    pts = jnp.asarray(rng.normal(size=(16, 2)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(1, 2)).astype(np.float32))
    a, d = kmeans.kmeans_assign(pts, cen, block=8)
    assert np.all(np.asarray(a) == 0)
    assert_allclose(
        np.asarray(d), np.sum((np.asarray(pts) - np.asarray(cen)) ** 2, axis=1),
        rtol=1e-4, atol=1e-6,
    )


def test_kmeans_assign_point_on_centroid():
    cen = jnp.asarray([[0.0, 0.0], [10.0, 10.0]], jnp.float32)
    pts = jnp.tile(cen, (4, 1))  # 8 points, alternating exactly on centroids
    a, d = kmeans.kmeans_assign(pts, cen, block=8)
    assert np.array_equal(np.asarray(a), np.tile([0, 1], 4))
    assert_allclose(np.asarray(d), np.zeros(8), atol=1e-6)


# ---------------------------------------------------------------------------
# backproject
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    a_blocks=st.integers(1, 4),
    angle_block=st.sampled_from([4, 8]),
    nd=st.sampled_from([32, 48]),
    hw=st.sampled_from([(16, 16), (24, 16), (32, 32)]),
)
def test_backproject_matches_ref(seed, a_blocks, angle_block, nd, hw):
    rng = _rng(seed)
    a = a_blocks * angle_block
    h, w = hw
    sino = jnp.asarray(rng.normal(size=(a, nd)).astype(np.float32))
    thetas = ref.thetas_for(a)
    out_pl = tomo.backproject(
        sino, jnp.cos(thetas), jnp.sin(thetas), h=h, w=w, angle_block=angle_block
    )
    out_rf = ref.backproject_ref(sino, thetas, h, w)
    assert_allclose(np.asarray(out_pl), np.asarray(out_rf), rtol=1e-4, atol=1e-4)


def test_backproject_production_shape():
    rng = _rng(3)
    sino = jnp.asarray(
        rng.normal(size=(params.N_ANGLES, params.N_DET)).astype(np.float32)
    )
    thetas = ref.thetas_for(params.N_ANGLES)
    out_pl = tomo.backproject(
        sino,
        jnp.cos(thetas),
        jnp.sin(thetas),
        h=params.IMG_H,
        w=params.IMG_W,
        angle_block=params.ANGLE_BLOCK,
    )
    out_rf = ref.backproject_ref(sino, thetas, params.IMG_H, params.IMG_W)
    assert_allclose(np.asarray(out_pl), np.asarray(out_rf), rtol=1e-4, atol=1e-4)


def test_backproject_zero_sino_is_zero_image():
    a, nd = 16, 32
    thetas = ref.thetas_for(a)
    out = tomo.backproject(
        jnp.zeros((a, nd), jnp.float32),
        jnp.cos(thetas),
        jnp.sin(thetas),
        h=16,
        w=16,
        angle_block=8,
    )
    assert_allclose(np.asarray(out), np.zeros((16, 16)), atol=0)


def test_backproject_uniform_sino_center_value():
    # A constant sinogram backprojects to ~pi * c at the image center
    # (every angle contributes c, scaled by pi/A * A).
    a, nd = 32, 64
    c = 2.5
    thetas = ref.thetas_for(a)
    out = tomo.backproject(
        jnp.full((a, nd), c, jnp.float32),
        jnp.cos(thetas),
        jnp.sin(thetas),
        h=17,
        w=17,
        angle_block=8,
    )
    assert_allclose(float(out[8, 8]), np.pi * c, rtol=1e-4)


# ---------------------------------------------------------------------------
# radon (forward projection)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    a_blocks=st.integers(1, 3),
    angle_block=st.sampled_from([4, 8]),
    nd=st.sampled_from([24, 40]),
    n_ray=st.sampled_from([16, 32]),
    hw=st.sampled_from([(16, 16), (16, 24)]),
)
def test_radon_matches_ref(seed, a_blocks, angle_block, nd, n_ray, hw):
    rng = _rng(seed)
    a = a_blocks * angle_block
    h, w = hw
    img = jnp.asarray(rng.normal(size=(h, w)).astype(np.float32))
    thetas = ref.thetas_for(a)
    out_pl = tomo.radon(
        img, jnp.cos(thetas), jnp.sin(thetas), nd=nd, n_ray=n_ray,
        angle_block=angle_block,
    )
    out_rf = ref.radon_ref(img, thetas, nd, n_ray)
    assert_allclose(np.asarray(out_pl), np.asarray(out_rf), rtol=1e-4, atol=1e-4)


def test_radon_production_shape():
    img = ref.shepp_logan(params.IMG_H, params.IMG_W)
    thetas = ref.thetas_for(params.N_ANGLES)
    out_pl = tomo.radon(
        img,
        jnp.cos(thetas),
        jnp.sin(thetas),
        nd=params.N_DET,
        n_ray=params.N_RAY,
        angle_block=params.ANGLE_BLOCK,
    )
    out_rf = ref.radon_ref(img, thetas, params.N_DET, params.N_RAY)
    assert_allclose(np.asarray(out_pl), np.asarray(out_rf), rtol=1e-4, atol=2e-4)


def test_radon_mass_conservation():
    # Every projection of a non-negative image sums to ~ the image mass
    # (rays cover the whole support when Nd and n_ray are large enough).
    img = ref.shepp_logan(32, 32)
    thetas = ref.thetas_for(16)
    out = tomo.radon(
        img, jnp.cos(thetas), jnp.sin(thetas), nd=64, n_ray=64, angle_block=8
    )
    mass = float(jnp.sum(img))
    sums = np.asarray(jnp.sum(out, axis=1))
    assert_allclose(sums, mass, rtol=0.05)


def test_radon_zero_angle_is_column_sum():
    # theta = 0: t = x, ray integrates over y -> projection == column sums.
    rng = _rng(11)
    h = w = 16
    img = jnp.asarray(rng.uniform(size=(h, w)).astype(np.float32))
    # Single angle block with theta=0 padded by other angles.
    thetas = jnp.zeros((4,), jnp.float32)
    out = tomo.radon(
        img, jnp.cos(thetas), jnp.sin(thetas), nd=w, n_ray=h, angle_block=4
    )
    col_sums = np.asarray(jnp.sum(img, axis=0))
    assert_allclose(np.asarray(out[0]), col_sums, rtol=1e-4, atol=1e-4)
