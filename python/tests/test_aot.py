"""AOT path: every artifact lowers to loadable HLO text with the
signatures the Rust runtime expects, and the emitted numbers match the
live-JAX evaluation when executed through an XLA client round trip.
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc
from numpy.testing import assert_allclose

from compile import aot, model, params
from compile.kernels import ref


def test_all_artifacts_lower_to_hlo_text():
    for name, (fn, args) in model.example_args().items():
        lowered = jax.jit(fn).lower(*args)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text, f"{name}: missing entry computation"


def test_build_writes_manifest_and_data(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.build(out)
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    names = set(model.example_args().keys())
    assert set(manifest["artifacts"].keys()) == names
    for name, meta in manifest["artifacts"].items():
        assert os.path.exists(os.path.join(out, meta["file"]))
        assert meta["inputs"] and meta["outputs"]
    phantom = np.fromfile(os.path.join(out, "phantom.bin"), dtype="<f4")
    assert phantom.size == params.IMG_H * params.IMG_W
    sino = np.fromfile(os.path.join(out, "template_sinogram.bin"), dtype="<f4")
    assert sino.size == params.N_ANGLES * params.N_DET
    # The template sinogram is the forward projection of the phantom.
    img = phantom.reshape(params.IMG_H, params.IMG_W)
    thetas = ref.thetas_for(params.N_ANGLES)
    expect = np.asarray(
        ref.radon_ref(jnp.asarray(img), thetas, params.N_DET, params.N_RAY)
    )
    assert_allclose(sino.reshape(params.N_ANGLES, params.N_DET), expect, atol=1e-3)


def test_hlo_text_parses_back():
    """HLO text must survive the same text parser the Rust runtime uses.

    (jax >= 0.5 can't *execute* XlaComputations through the new jaxlib
    client API anymore; actual execution of the text artifacts is
    covered by the Rust runtime integration tests against the golden
    vectors below.)
    """
    for name, (fn, args) in model.example_args().items():
        text = aot.to_hlo_text(jax.jit(fn).lower(*args))
        mod = xc._xla.hlo_module_from_text(text)
        assert mod.as_serialized_hlo_module_proto(), f"{name}: empty proto"


def test_golden_vectors_match_live_eval(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.build(out)
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    for name, (fn, args) in model.example_args().items():
        meta = manifest["artifacts"][name]
        concrete = []
        for i, sig in enumerate(meta["inputs"]):
            arr = np.fromfile(
                os.path.join(out, "testvectors", f"{name}.in{i}.bin"),
                dtype=np.dtype(sig["dtype"]).newbyteorder("<"),
            ).reshape(sig["shape"])
            concrete.append(arr)
        live = jax.tree_util.tree_leaves(jax.jit(fn)(*[jnp.asarray(a) for a in concrete]))
        for i, (sig, want) in enumerate(zip(meta["outputs"], live)):
            got = np.fromfile(
                os.path.join(out, "testvectors", f"{name}.out{i}.bin"),
                dtype=np.dtype(sig["dtype"]).newbyteorder("<"),
            ).reshape(sig["shape"])
            assert_allclose(got, np.asarray(want), rtol=1e-4, atol=1e-4)
