"""L2 correctness: model-level behaviour of the AOT artifacts.

These tests exercise the exact functions that ``aot.py`` lowers, at the
exact production shapes, plus algorithmic invariants (EM monotonicity,
streaming-update fixed points, reconstruction fidelity ordering).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model, params
from compile.kernels import ref

SETTINGS = dict(max_examples=15, deadline=None)


def _phantom_sino():
    img = ref.shepp_logan(params.IMG_H, params.IMG_W)
    thetas = ref.thetas_for(params.N_ANGLES)
    sino = ref.radon_ref(img, thetas, params.N_DET, params.N_RAY)
    return img, sino


# ---------------------------------------------------------------------------
# KMeans score / update
# ---------------------------------------------------------------------------


def test_kmeans_score_matches_ref_stats():
    rng = np.random.default_rng(0)
    pts = jnp.asarray(
        rng.normal(size=(params.KMEANS_POINTS, params.KMEANS_DIM)).astype(np.float32)
    )
    cen = jnp.asarray(
        rng.normal(size=(params.KMEANS_K, params.KMEANS_DIM)).astype(np.float32)
    )
    assign, counts, sums, inertia = model.kmeans_score(pts, cen)
    a_rf, d_rf = ref.kmeans_assign_ref(pts, cen)
    c_rf, s_rf = ref.kmeans_stats_ref(pts, a_rf, params.KMEANS_K)
    assert np.array_equal(np.asarray(assign), np.asarray(a_rf))
    assert_allclose(np.asarray(counts), np.asarray(c_rf))
    assert_allclose(np.asarray(sums), np.asarray(s_rf), rtol=1e-4, atol=1e-2)
    assert_allclose(float(inertia), float(jnp.sum(d_rf)), rtol=1e-4)
    # Counts partition the batch.
    assert float(jnp.sum(counts)) == params.KMEANS_POINTS


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_kmeans_update_matches_ref(seed):
    rng = np.random.default_rng(seed)
    k, d = params.KMEANS_K, params.KMEANS_DIM
    cen = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 100, size=(k,)).astype(np.float32))
    sums = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32) * 10)
    counts = jnp.asarray(
        rng.integers(0, 50, size=(k,)).astype(np.float32)
    )
    new_c, new_w = model.kmeans_update(cen, w, sums, counts)
    rf_c, rf_w = ref.kmeans_update_ref(cen, w, sums, counts, params.KMEANS_DECAY)
    assert_allclose(np.asarray(new_c), np.asarray(rf_c), rtol=1e-5, atol=1e-5)
    assert_allclose(np.asarray(new_w), np.asarray(rf_w), rtol=1e-5)


def test_kmeans_update_empty_batch_keeps_centroids():
    k, d = params.KMEANS_K, params.KMEANS_DIM
    cen = jnp.arange(k * d, dtype=jnp.float32).reshape(k, d)
    w = jnp.full((k,), 10.0, jnp.float32)
    new_c, new_w = model.kmeans_update(
        cen, w, jnp.zeros((k, d), jnp.float32), jnp.zeros((k,), jnp.float32)
    )
    assert_allclose(np.asarray(new_c), np.asarray(cen))
    assert_allclose(np.asarray(new_w), 10.0 * params.KMEANS_DECAY)


def test_kmeans_update_fresh_model_takes_batch_mean():
    # weights == 0: the update must land exactly on the batch means.
    k, d = params.KMEANS_K, params.KMEANS_DIM
    rng = np.random.default_rng(5)
    cen = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    sums = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    counts = jnp.full((k,), 4.0, jnp.float32)
    new_c, new_w = model.kmeans_update(
        cen, jnp.zeros((k,), jnp.float32), sums, counts
    )
    assert_allclose(np.asarray(new_c), np.asarray(sums) / 4.0, rtol=1e-5)
    assert_allclose(np.asarray(new_w), 4.0)


def test_kmeans_converges_on_separated_clusters():
    # Streaming score->update loop recovers well-separated cluster centers.
    rng = np.random.default_rng(42)
    k, d, n = params.KMEANS_K, params.KMEANS_DIM, params.KMEANS_POINTS
    true_centers = rng.uniform(-50, 50, size=(k, d)).astype(np.float32)
    cen = jnp.asarray(true_centers + rng.normal(size=(k, d)).astype(np.float32))
    w = jnp.zeros((k,), jnp.float32)
    for _ in range(5):
        labels = rng.integers(0, k, size=n)
        pts = true_centers[labels] + rng.normal(scale=0.1, size=(n, d)).astype(
            np.float32
        )
        _, counts, sums, _ = model.kmeans_score(jnp.asarray(pts), cen)
        cen, w = model.kmeans_update(cen, w, sums, counts)
    err = np.max(np.abs(np.asarray(cen) - true_centers))
    assert err < 0.05, f"centroids did not converge: max err {err}"


# ---------------------------------------------------------------------------
# Reconstruction models
# ---------------------------------------------------------------------------


def test_gridrec_matches_ref_fbp():
    _, sino = _phantom_sino()
    out = model.gridrec(sino)
    thetas = ref.thetas_for(params.N_ANGLES)
    out_rf = ref.fbp_ref(sino, thetas, params.IMG_H, params.IMG_W)
    assert_allclose(np.asarray(out), np.asarray(out_rf), rtol=1e-3, atol=1e-3)


def test_gridrec_reconstructs_phantom():
    img, sino = _phantom_sino()
    out = model.gridrec(sino)
    interior = np.asarray(out)[16:-16, 16:-16]
    truth = np.asarray(img)[16:-16, 16:-16]
    rmse = float(np.sqrt(np.mean((interior - truth) ** 2)))
    assert rmse < 0.12, f"FBP rmse too high: {rmse}"


def test_mlem_matches_ref():
    _, sino = _phantom_sino()
    out = jax.jit(model.mlem)(sino)
    thetas = ref.thetas_for(params.N_ANGLES)
    out_rf = ref.mlem_ref(
        sino,
        thetas,
        params.IMG_H,
        params.IMG_W,
        params.N_DET,
        params.N_RAY,
        params.MLEM_ITERS,
    )
    assert_allclose(np.asarray(out), np.asarray(out_rf), rtol=1e-2, atol=1e-3)


def test_mlem_error_decreases_with_iterations():
    img, sino = _phantom_sino()
    thetas = ref.thetas_for(params.N_ANGLES)
    errs = []
    for iters in (1, 4, 16):
        out = ref.mlem_ref(
            sino, thetas, params.IMG_H, params.IMG_W, params.N_DET, params.N_RAY,
            iters,
        )
        errs.append(float(jnp.sqrt(jnp.mean((out - img) ** 2))))
    assert errs[2] < errs[1] < errs[0], f"EM not monotone: {errs}"


def test_mlem_nonnegative():
    _, sino = _phantom_sino()
    out = jax.jit(model.mlem)(sino)
    assert float(jnp.min(out)) >= 0.0


def test_radon_forward_matches_ref():
    img, sino = _phantom_sino()
    out = model.radon_forward(img)
    assert_allclose(np.asarray(out), np.asarray(sino), rtol=1e-3, atol=2e-4)


def test_fbp_then_radon_roundtrip():
    # radon(gridrec(sino)) ~ sino on the phantom (consistency of the pair).
    _, sino = _phantom_sino()
    rec = model.gridrec(sino)
    sino2 = model.radon_forward(rec)
    # Compare in the central detector region where the phantom lives.
    c = np.asarray(sino)[:, 48:-48]
    c2 = np.asarray(sino2)[:, 48:-48]
    rel = np.linalg.norm(c - c2) / np.linalg.norm(c)
    assert rel < 0.25, f"roundtrip relative error {rel}"
