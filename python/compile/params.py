"""Shared geometry / model parameters for the Pilot-Streaming compute payloads.

These constants define the fixed AOT shapes shared between the Python
compile path (L1 Pallas kernels, L2 JAX models) and the Rust runtime
(which reads them back from ``artifacts/manifest.json``).

The sizes mirror the paper's Mini-App workloads (section 6):

* KMeans messages carry 5,000 3-D points and are scored against 10
  centroids (paper section 6.4: "a streaming KMeans application that
  trains a model with 10 centroids").
* Light-source messages carry one APS-format frame whose sinogram we fix
  at ``N_ANGLES x N_DET``; reconstruction output is ``IMG_H x IMG_W``.
  The serialized message is padded to ~2 MB to match the paper's APS
  message size, of which the sinogram is the compute-relevant payload.
"""

# --- KMeans (paper: 5000 points / message, ~0.32 MB serialized, K=10) ---
KMEANS_POINTS = 5000
KMEANS_DIM = 3
KMEANS_K = 10

# --- Light source tomography ---
N_ANGLES = 96  # projection angles over [0, pi)
N_DET = 192  # detector bins (>= image diagonal 128*sqrt(2) ~ 182)
IMG_H = 128
IMG_W = 128
N_RAY = 192  # integration steps along each ray (forward projection)

# ML-EM iterations per message.  The paper reports GridRec ~3x faster
# than ML-EM (63 vs 22 msg/s); 4 inner iterations lands our FBP/ML-EM
# cost ratio in the same regime on CPU.
MLEM_ITERS = 4

# Streaming KMeans decay factor (MLlib-style exponential forgetting).
KMEANS_DECAY = 0.9

# Pallas block sizes (L1 tiling).
KMEANS_BLOCK = 500  # points per VMEM block; 5000/500 = 10 grid steps
ANGLE_BLOCK = 16  # angles per backprojection block; 96/16 = 6 steps

MANIFEST = {
    "kmeans": {
        "n_points": KMEANS_POINTS,
        "dim": KMEANS_DIM,
        "k": KMEANS_K,
        "decay": KMEANS_DECAY,
        "block": KMEANS_BLOCK,
    },
    "tomo": {
        "n_angles": N_ANGLES,
        "n_det": N_DET,
        "img_h": IMG_H,
        "img_w": IMG_W,
        "n_ray": N_RAY,
        "mlem_iters": MLEM_ITERS,
        "angle_block": ANGLE_BLOCK,
    },
}
