"""AOT compile path: lower every L2 model to HLO text + data artifacts.

Run once at build time (``make artifacts``); Python is never on the
request path.  For each artifact in :func:`model.example_args` this
writes ``artifacts/<name>.hlo.txt``; it also emits:

* ``manifest.json`` — shapes/params the Rust runtime needs to marshal
  ``Literal``s (mirrors ``params.MANIFEST``) plus per-artifact
  input/output signatures.
* ``template_sinogram.bin`` — f32-LE sinogram of the Shepp-Logan
  phantom: the MASS ``template`` source payload (APS-format analogue).
* ``phantom.bin`` — f32-LE ground-truth image, used by examples to
  report reconstruction error.
* ``testvectors/<name>.in<i>.bin / .out<i>.bin`` — golden input/output
  vectors per artifact, produced by live-JAX evaluation.  The Rust
  runtime's integration tests execute each compiled artifact on the
  ``.in*`` vectors and assert allclose against ``.out*`` — the
  cross-language round-trip check (jax -> HLO text -> PJRT-in-Rust).

Interchange is HLO *text*, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, params
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(avals):
    out = []
    for v in jax.tree_util.tree_leaves(avals):
        out.append({"shape": list(v.shape), "dtype": str(v.dtype)})
    return out


def _example_inputs(name, args):
    """Deterministic concrete inputs for the golden test vectors."""
    rng = np.random.default_rng(abs(hash(name)) % (2**32))
    out = []
    for a in args:
        arr = rng.uniform(0.1, 1.0, size=a.shape).astype(a.dtype)
        out.append(arr)
    return out


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    vec_dir = os.path.join(out_dir, "testvectors")
    os.makedirs(vec_dir, exist_ok=True)
    manifest = dict(params.MANIFEST)
    manifest["artifacts"] = {}

    for name, (fn, args) in model.example_args().items():
        jitted = jax.jit(fn)
        lowered = jitted.lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *args)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": _sig(args),
            "outputs": _sig(out_avals),
        }
        # Golden vectors: live-JAX evaluation on deterministic inputs.
        concrete = _example_inputs(name, args)
        results = jax.tree_util.tree_leaves(jitted(*concrete))
        for i, arr in enumerate(concrete):
            arr.tofile(os.path.join(vec_dir, f"{name}.in{i}.bin"))
        for i, arr in enumerate(results):
            np.asarray(arr).tofile(os.path.join(vec_dir, f"{name}.out{i}.bin"))
        print(f"wrote {path} ({len(text)} chars, {len(concrete)} in / "
              f"{len(results)} out vectors)")

    # Data artifacts: phantom image + its sinogram (the MASS template).
    img_j = ref.shepp_logan(params.IMG_H, params.IMG_W)
    thetas = ref.thetas_for(params.N_ANGLES)
    sino = np.asarray(
        ref.radon_ref(img_j, thetas, params.N_DET, params.N_RAY), dtype=np.float32
    )
    img = np.asarray(img_j, dtype=np.float32)
    img.tofile(os.path.join(out_dir, "phantom.bin"))
    sino.tofile(os.path.join(out_dir, "template_sinogram.bin"))
    print(f"wrote phantom.bin ({img.nbytes} B), template_sinogram.bin ({sino.nbytes} B)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="output directory")
    args = p.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
