"""L1 Pallas kernel: KMeans nearest-centroid assignment.

The hot spot of the paper's streaming-KMeans Mini-App (section 6.4) is
scoring each incoming mini-batch against the model: O(n_points * k)
distance evaluations per message.

TPU adaptation (DESIGN.md section Hardware-Adaptation): points are tiled
into VMEM-sized blocks along the batch dimension; the centroid table is
tiny and kept resident.  Squared distances are computed via the matmul
expansion ``|p|^2 - 2 p.c^T + |c|^2`` so the dominant FLOPs land on the
MXU rather than the VPU.  The kernel runs ``interpret=True`` here (CPU
PJRT cannot execute Mosaic custom-calls); on a real TPU the same
BlockSpecs express the HBM<->VMEM schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(p_ref, c_ref, assign_ref, dist_ref):
    """One block of points vs. the full (small) centroid table."""
    p = p_ref[...]  # [B, D]
    c = c_ref[...]  # [K, D]
    p2 = jnp.sum(p * p, axis=1, keepdims=True)  # [B, 1]
    c2 = jnp.sum(c * c, axis=1)[None, :]  # [1, K]
    # MXU-friendly expansion; clamp tiny negative rounding artifacts.
    d2 = jnp.maximum(p2 - 2.0 * (p @ c.T) + c2, 0.0)  # [B, K]
    assign_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d2, axis=1)


@functools.partial(jax.jit, static_argnames=("block",))
def kmeans_assign(points, centroids, *, block=500):
    """Pallas nearest-centroid assignment.

    Args:
      points: ``[N, D]`` f32; ``N`` must be a multiple of ``block``.
      centroids: ``[K, D]`` f32.
      block: points per VMEM tile.

    Returns:
      ``(assign [N] i32, min_sq_dist [N] f32)`` — matches
      :func:`ref.kmeans_assign_ref`.
    """
    n, d = points.shape
    k, _ = centroids.shape
    if n % block != 0:
        raise ValueError(f"N={n} not a multiple of block={block}")
    grid = (n // block,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(points, centroids)
