"""Pure-jnp oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has a reference implementation here
written with plain ``jax.numpy`` ops only.  The pytest suite asserts
``assert_allclose(kernel(...), ref(...))`` — this is the core
correctness signal for the compute layer.

Geometry conventions (shared by forward projection and backprojection):

* image pixel (i, j) sits at centered coordinates
  ``x = j - (W-1)/2``, ``y = (H-1)/2 - i`` (y up, unit pixel spacing);
* a projection at angle ``theta`` maps (x, y) to detector coordinate
  ``t = x*cos(theta) + y*sin(theta)``, detector bin ``t + (Nd-1)/2``;
* samples falling outside the detector (or image) contribute zero;
* interpolation is linear in detector space (backprojection) and
  bilinear in image space (forward projection).
"""

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# KMeans
# ---------------------------------------------------------------------------


def kmeans_assign_ref(points, centroids):
    """Assign each point to the nearest centroid.

    Args:
      points: ``[N, D]`` float array.
      centroids: ``[K, D]`` float array.

    Returns:
      ``(assign [N] int32, min_sq_dist [N] float32)``.
    """
    d2 = jnp.sum((points[:, None, :] - centroids[None, :, :]) ** 2, axis=-1)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)


def kmeans_stats_ref(points, assign, k):
    """Per-cluster counts and coordinate sums for a mini-batch."""
    onehot = (assign[:, None] == jnp.arange(k)[None, :]).astype(points.dtype)
    counts = jnp.sum(onehot, axis=0)
    sums = onehot.T @ points
    return counts, sums


def kmeans_update_ref(centroids, weights, batch_sums, batch_counts, decay):
    """MLlib-style streaming KMeans centroid update with forgetting.

    ``c_t = (c_{t-1} * w_{t-1} * a + sum_t) / (w_{t-1} * a + m_t)``
    where empty clusters keep their previous centroid.
    """
    w_old = weights * decay
    denom = w_old + batch_counts
    safe = jnp.where(denom > 0, denom, 1.0)
    new_c = (centroids * w_old[:, None] + batch_sums) / safe[:, None]
    new_c = jnp.where((denom > 0)[:, None], new_c, centroids)
    return new_c, denom


# ---------------------------------------------------------------------------
# Tomography
# ---------------------------------------------------------------------------


def _pixel_grid(h, w):
    ys = ((h - 1) / 2.0 - jnp.arange(h, dtype=jnp.float32))[:, None]  # [H,1]
    xs = (jnp.arange(w, dtype=jnp.float32) - (w - 1) / 2.0)[None, :]  # [1,W]
    return xs, ys


def backproject_ref(sino, thetas, h, w):
    """Unfiltered backprojection of ``sino [A, Nd]`` onto ``[h, w]``.

    Linear interpolation in detector space; out-of-detector samples are
    zero.  Scaled by ``pi / A`` (Riemann sum over angle).
    """
    a, nd = sino.shape
    xs, ys = _pixel_grid(h, w)

    def body(acc, inp):
        theta, row = inp
        t = xs * jnp.cos(theta) + ys * jnp.sin(theta) + (nd - 1) / 2.0
        i0 = jnp.clip(jnp.floor(t).astype(jnp.int32), 0, nd - 2)
        frac = t - i0.astype(jnp.float32)
        v = row[i0] * (1.0 - frac) + row[i0 + 1] * frac
        valid = (t >= 0.0) & (t <= nd - 1.0)
        return acc + jnp.where(valid, v, 0.0), None

    img, _ = jax.lax.scan(body, jnp.zeros((h, w), jnp.float32), (thetas, sino))
    return img * (jnp.pi / a)


def bilinear_sample_ref(img, rows, cols):
    """Bilinear sample ``img`` at fractional (row, col); zero outside."""
    h, w = img.shape
    r0 = jnp.clip(jnp.floor(rows).astype(jnp.int32), 0, h - 2)
    c0 = jnp.clip(jnp.floor(cols).astype(jnp.int32), 0, w - 2)
    fr = rows - r0.astype(jnp.float32)
    fc = cols - c0.astype(jnp.float32)
    v00 = img[r0, c0]
    v01 = img[r0, c0 + 1]
    v10 = img[r0 + 1, c0]
    v11 = img[r0 + 1, c0 + 1]
    v = (
        v00 * (1 - fr) * (1 - fc)
        + v01 * (1 - fr) * fc
        + v10 * fr * (1 - fc)
        + v11 * fr * fc
    )
    valid = (rows >= 0) & (rows <= h - 1) & (cols >= 0) & (cols <= w - 1)
    return jnp.where(valid, v, 0.0)


def radon_ref(img, thetas, nd, n_ray):
    """Forward (Radon) projection of ``img`` -> sinogram ``[A, Nd]``.

    Rotate-and-sum: for each angle, integrate the image along rays
    parameterized by detector coordinate ``t`` and ray coordinate ``s``.
    """
    h, w = img.shape
    tc = jnp.arange(nd, dtype=jnp.float32) - (nd - 1) / 2.0  # [Nd]
    sc = jnp.arange(n_ray, dtype=jnp.float32) - (n_ray - 1) / 2.0  # [Ns]

    def one_angle(theta):
        ct, st = jnp.cos(theta), jnp.sin(theta)
        x = tc[:, None] * ct - sc[None, :] * st  # [Nd, Ns]
        y = tc[:, None] * st + sc[None, :] * ct
        cols = x + (w - 1) / 2.0
        rows = (h - 1) / 2.0 - y
        return jnp.sum(bilinear_sample_ref(img, rows, cols), axis=1)

    return jax.vmap(one_angle)(thetas)


def ramp_filter_ref(sino):
    """Frequency-domain ramp filter (GridRec / FBP), row-wise over angles."""
    _, nd = sino.shape
    freqs = jnp.fft.fftfreq(nd)
    ramp = jnp.abs(freqs)
    return jnp.real(
        jnp.fft.ifft(jnp.fft.fft(sino, axis=1) * ramp[None, :], axis=1)
    ).astype(jnp.float32)


def fbp_ref(sino, thetas, h, w):
    """Filtered backprojection (our GridRec analogue)."""
    return backproject_ref(ramp_filter_ref(sino), thetas, h, w)


def mlem_ref(sino, thetas, h, w, nd, n_ray, iters):
    """Maximum-likelihood EM reconstruction (TomoPy ML-EM analogue).

    ``x <- x / s * A^T(y / (A x))`` with ``s = A^T 1`` computed once from
    the fixed geometry; projections clamped away from zero for stability.
    """
    eps = 1e-6
    ones = jnp.ones_like(sino)
    sens = backproject_ref(ones, thetas, h, w)
    sens = jnp.where(sens > eps, sens, 1.0)
    x0 = jnp.ones((h, w), jnp.float32)

    def body(x, _):
        proj = radon_ref(x, thetas, nd, n_ray)
        ratio = sino / jnp.maximum(proj, eps)
        x = x * backproject_ref(ratio, thetas, h, w) / sens
        return x, None

    x, _ = jax.lax.scan(body, x0, None, length=iters)
    return x


def thetas_for(n_angles):
    """The fixed angle grid: ``n_angles`` samples over [0, pi)."""
    return jnp.arange(n_angles, dtype=jnp.float32) * (jnp.pi / n_angles)


def shepp_logan(h, w):
    """A small Shepp-Logan-style phantom used as the MASS template image."""
    ys = ((h - 1) / 2.0 - jnp.arange(h, dtype=jnp.float32))[:, None] / (h / 2.0)
    xs = (jnp.arange(w, dtype=jnp.float32) - (w - 1) / 2.0)[None, :] / (w / 2.0)

    def ellipse(cx, cy, ax, ay, phi, val):
        c, s = jnp.cos(phi), jnp.sin(phi)
        xr = (xs - cx) * c + (ys - cy) * s
        yr = -(xs - cx) * s + (ys - cy) * c
        return jnp.where((xr / ax) ** 2 + (yr / ay) ** 2 <= 1.0, val, 0.0)

    img = ellipse(0.0, 0.0, 0.72, 0.92, 0.0, 1.0)
    img = img + ellipse(0.0, -0.018, 0.655, 0.854, 0.0, -0.8)
    img = img + ellipse(0.22, 0.0, 0.11, 0.31, -0.4, -0.2)
    img = img + ellipse(-0.22, 0.0, 0.16, 0.41, 0.4, -0.2)
    img = img + ellipse(0.0, 0.35, 0.21, 0.25, 0.0, 0.3)
    img = img + ellipse(0.0, 0.1, 0.046, 0.046, 0.0, 0.2)
    img = img + ellipse(-0.08, -0.605, 0.046, 0.023, 0.0, 0.2)
    img = img + ellipse(0.06, -0.605, 0.046, 0.046, 0.0, 0.2)
    return jnp.maximum(img, 0.0).astype(jnp.float32)
