"""L1 Pallas kernels: tomographic backprojection and forward projection.

These are the hot spots of the paper's light-source Mini-App (section
6.4): GridRec-style filtered backprojection and iterative ML-EM both
spend their FLOPs in (back)projection sweeps over the projection angles.

TPU adaptation (DESIGN.md section Hardware-Adaptation): TomoPy's CPU
implementation parallelizes over slices/angles with OpenMP; here the
angle axis is tiled into blocks and the image accumulator stays resident
in VMEM across grid steps (output BlockSpec maps every step to the same
block — the revisiting-output accumulation idiom).  Per-angle detector
interpolation is expressed as vectorized gathers over the pixel grid.
``interpret=True`` is mandatory on CPU PJRT; the BlockSpecs are the
HBM<->VMEM schedule a real TPU would use.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pixel_grid(h, w):
    ys = ((h - 1) / 2.0 - jax.lax.broadcasted_iota(jnp.float32, (h, w), 0))
    xs = jax.lax.broadcasted_iota(jnp.float32, (h, w), 1) - (w - 1) / 2.0
    return xs, ys


def _backproject_kernel(sino_ref, cos_ref, sin_ref, img_ref, *, h, w, nd, scale):
    """Accumulate one block of angles into the resident image block."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        img_ref[...] = jnp.zeros_like(img_ref)

    sino = sino_ref[...]  # [BA, Nd]
    cos_t = cos_ref[...]  # [BA]
    sin_t = sin_ref[...]  # [BA]
    ba = sino.shape[0]
    xs, ys = _pixel_grid(h, w)
    xf = xs.reshape(-1)  # [P]
    yf = ys.reshape(-1)

    # t[a, p] = x_p cos(theta_a) + y_p sin(theta_a) + center
    t = cos_t[:, None] * xf[None, :] + sin_t[:, None] * yf[None, :] + (nd - 1) / 2.0
    i0 = jnp.clip(jnp.floor(t).astype(jnp.int32), 0, nd - 2)  # [BA, P]
    frac = t - i0.astype(jnp.float32)
    v0 = jnp.take_along_axis(sino, i0, axis=1)
    v1 = jnp.take_along_axis(sino, i0 + 1, axis=1)
    v = v0 * (1.0 - frac) + v1 * frac
    valid = (t >= 0.0) & (t <= nd - 1.0)
    contrib = jnp.sum(jnp.where(valid, v, 0.0), axis=0).reshape(h, w)
    img_ref[...] += contrib * scale


@functools.partial(jax.jit, static_argnames=("h", "w", "angle_block"))
def backproject(sino, cos_t, sin_t, *, h, w, angle_block=16):
    """Pallas backprojection: ``sino [A, Nd]`` -> image ``[h, w]``.

    Matches :func:`ref.backproject_ref` (which takes ``thetas``; here the
    caller passes precomputed ``cos/sin`` tables so the fixed geometry is
    hoisted out of the kernel).
    """
    a, nd = sino.shape
    if a % angle_block != 0:
        raise ValueError(f"A={a} not a multiple of angle_block={angle_block}")
    grid = (a // angle_block,)
    kernel = functools.partial(
        _backproject_kernel, h=h, w=w, nd=nd, scale=float(jnp.pi) / a
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((angle_block, nd), lambda i: (i, 0)),
            pl.BlockSpec((angle_block,), lambda i: (i,)),
            pl.BlockSpec((angle_block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((h, w), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(sino, cos_t, sin_t)


def _radon_kernel(img_ref, cos_ref, sin_ref, sino_ref, *, nd, n_ray):
    """Forward-project the resident image for one block of angles."""
    img = img_ref[...]  # [H, W]
    h, w = img.shape
    cos_t = cos_ref[...]  # [BA]
    sin_t = sin_ref[...]
    ba = cos_t.shape[0]

    tc = jax.lax.iota(jnp.float32, nd) - (nd - 1) / 2.0  # [Nd]
    sc = jax.lax.iota(jnp.float32, n_ray) - (n_ray - 1) / 2.0  # [Ns]
    # Sample coordinates for all (angle, det, ray) triples.
    x = (
        tc[None, :, None] * cos_t[:, None, None]
        - sc[None, None, :] * sin_t[:, None, None]
    )  # [BA, Nd, Ns]
    y = (
        tc[None, :, None] * sin_t[:, None, None]
        + sc[None, None, :] * cos_t[:, None, None]
    )
    cols = x + (w - 1) / 2.0
    rows = (h - 1) / 2.0 - y
    r0 = jnp.clip(jnp.floor(rows).astype(jnp.int32), 0, h - 2)
    c0 = jnp.clip(jnp.floor(cols).astype(jnp.int32), 0, w - 2)
    fr = rows - r0.astype(jnp.float32)
    fc = cols - c0.astype(jnp.float32)
    flat = img.reshape(-1)

    def at(r, c):
        return jnp.take(flat, r * w + c)

    v = (
        at(r0, c0) * (1 - fr) * (1 - fc)
        + at(r0, c0 + 1) * (1 - fr) * fc
        + at(r0 + 1, c0) * fr * (1 - fc)
        + at(r0 + 1, c0 + 1) * fr * fc
    )
    valid = (rows >= 0) & (rows <= h - 1) & (cols >= 0) & (cols <= w - 1)
    sino_ref[...] = jnp.sum(jnp.where(valid, v, 0.0), axis=2)


@functools.partial(jax.jit, static_argnames=("nd", "n_ray", "angle_block"))
def radon(img, cos_t, sin_t, *, nd, n_ray, angle_block=16):
    """Pallas forward projection: image ``[H, W]`` -> ``sino [A, Nd]``.

    Matches :func:`ref.radon_ref`.
    """
    (a,) = cos_t.shape
    h, w = img.shape
    if a % angle_block != 0:
        raise ValueError(f"A={a} not a multiple of angle_block={angle_block}")
    grid = (a // angle_block,)
    kernel = functools.partial(_radon_kernel, nd=nd, n_ray=n_ray)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((h, w), lambda i: (0, 0)),
            pl.BlockSpec((angle_block,), lambda i: (i,)),
            pl.BlockSpec((angle_block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((angle_block, nd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a, nd), jnp.float32),
        interpret=True,
    )(img, cos_t, sin_t)
