"""L2 JAX models for the Pilot-Streaming Mini-App processors.

Each public function here is one AOT artifact: it is jitted, lowered to
HLO text by ``aot.py``, and executed from the Rust runtime
(``rust/src/runtime``) on the request path.  All shapes are fixed at
compile time (see ``params.py``); Python never runs at serving time.

Models:

* :func:`kmeans_score` — score one mini-batch against the centroid
  table: Pallas assignment kernel + per-cluster batch statistics.
* :func:`kmeans_update` — MLlib-style streaming centroid update with a
  decay factor (the "model update" half of Table 1).
* :func:`gridrec` — GridRec analogue: frequency-domain ramp filter +
  Pallas backprojection (the fast, direct reconstruction).
* :func:`mlem` — ML-EM analogue: fixed-iteration EM loop built from the
  Pallas forward/backprojection kernels (the slow, iterative method).
* :func:`radon_forward` — forward projection, exported for sinogram
  template generation and tests.
"""

import jax
import jax.numpy as jnp

from . import params
from .kernels import kmeans as kmeans_kernels
from .kernels import tomo as tomo_kernels
from .kernels import ref


def _geometry():
    thetas = ref.thetas_for(params.N_ANGLES)
    return jnp.cos(thetas), jnp.sin(thetas)


# ---------------------------------------------------------------------------
# KMeans
# ---------------------------------------------------------------------------


def kmeans_score(points, centroids):
    """Score a mini-batch of points against the model.

    Args:
      points: ``[N, D]`` f32.
      centroids: ``[K, D]`` f32.

    Returns:
      ``(assign [N] i32, counts [K] f32, sums [K, D] f32, inertia [] f32)``
      — everything the coordinator needs for both prediction and the
      subsequent model update, in a single fused artifact.
    """
    k = centroids.shape[0]
    assign, dist = kmeans_kernels.kmeans_assign(
        points, centroids, block=params.KMEANS_BLOCK
    )
    onehot = (assign[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :]).astype(
        points.dtype
    )
    counts = jnp.sum(onehot, axis=0)
    sums = onehot.T @ points
    inertia = jnp.sum(dist)
    return assign, counts, sums, inertia


def kmeans_update(centroids, weights, batch_sums, batch_counts):
    """Streaming centroid update with exponential forgetting.

    The decay factor is baked into the artifact (``params.KMEANS_DECAY``)
    so the hot path passes only the running state + batch statistics.
    Empty clusters keep their previous centroid.

    Returns ``(new_centroids [K, D], new_weights [K])``.
    """
    w_old = weights * params.KMEANS_DECAY
    denom = w_old + batch_counts
    safe = jnp.where(denom > 0, denom, 1.0)
    new_c = (centroids * w_old[:, None] + batch_sums) / safe[:, None]
    new_c = jnp.where((denom > 0)[:, None], new_c, centroids)
    return new_c, denom


# ---------------------------------------------------------------------------
# Light source reconstruction
# ---------------------------------------------------------------------------


def gridrec(sino):
    """GridRec analogue: ramp filter (FFT) + Pallas backprojection."""
    cos_t, sin_t = _geometry()
    nd = sino.shape[1]
    freqs = jnp.fft.fftfreq(nd)
    ramp = jnp.abs(freqs)
    filtered = jnp.real(
        jnp.fft.ifft(jnp.fft.fft(sino, axis=1) * ramp[None, :], axis=1)
    ).astype(jnp.float32)
    return tomo_kernels.backproject(
        filtered,
        cos_t,
        sin_t,
        h=params.IMG_H,
        w=params.IMG_W,
        angle_block=params.ANGLE_BLOCK,
    )


def mlem(sino):
    """ML-EM analogue: ``params.MLEM_ITERS`` EM iterations.

    ``x <- x / s * A^T(y / (A x))`` with the sensitivity image
    ``s = A^T 1`` folded into the artifact as a constant of the fixed
    geometry.
    """
    cos_t, sin_t = _geometry()
    h, w = params.IMG_H, params.IMG_W
    eps = 1e-6

    def bp(s):
        return tomo_kernels.backproject(
            s, cos_t, sin_t, h=h, w=w, angle_block=params.ANGLE_BLOCK
        )

    def fwd(x):
        return tomo_kernels.radon(
            x,
            cos_t,
            sin_t,
            nd=params.N_DET,
            n_ray=params.N_RAY,
            angle_block=params.ANGLE_BLOCK,
        )

    sens = bp(jnp.ones_like(sino))
    sens = jnp.where(sens > eps, sens, 1.0)
    x0 = jnp.ones((h, w), jnp.float32)

    def body(_, x):
        proj = fwd(x)
        ratio = sino / jnp.maximum(proj, eps)
        return x * bp(ratio) / sens

    return jax.lax.fori_loop(0, params.MLEM_ITERS, body, x0)


def radon_forward(img):
    """Forward projection of an image with the fixed experiment geometry."""
    cos_t, sin_t = _geometry()
    return tomo_kernels.radon(
        img,
        cos_t,
        sin_t,
        nd=params.N_DET,
        n_ray=params.N_RAY,
        angle_block=params.ANGLE_BLOCK,
    )


# ---------------------------------------------------------------------------
# Artifact registry (used by aot.py and the pytest suite)
# ---------------------------------------------------------------------------


def example_args():
    """``{artifact_name: (fn, example_args)}`` for every AOT artifact."""
    f32 = jnp.float32
    n, d, k = params.KMEANS_POINTS, params.KMEANS_DIM, params.KMEANS_K
    a, nd = params.N_ANGLES, params.N_DET
    h, w = params.IMG_H, params.IMG_W
    s = jax.ShapeDtypeStruct
    return {
        "kmeans_score": (kmeans_score, (s((n, d), f32), s((k, d), f32))),
        "kmeans_update": (
            kmeans_update,
            (s((k, d), f32), s((k,), f32), s((k, d), f32), s((k,), f32)),
        ),
        "gridrec": (gridrec, (s((a, nd), f32),)),
        "mlem": (mlem, (s((a, nd), f32),)),
        "radon": (radon_forward, (s((h, w), f32),)),
    }
